"""Train the ~135M-parameter smollm architecture for a few hundred steps.

Uses the full training substrate: deterministic data pipeline, AdamW,
atomic checkpointing with resume, straggler monitor.  At the default
reduced sequence length this runs on CPU in a few minutes; pass --full
for the real 135M config (slow on CPU -- sized for the TPU mesh).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import tempfile

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    ckdir = tempfile.mkdtemp(prefix="lm_ck_")
    losses = train_main([
        "--arch", "smollm-135m",
        *([] if args.full else ["--smoke"]),
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", ckdir, "--ckpt-every", "100",
        "--log-every", "25",
    ])
    drop = losses[0] - losses[-1]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
    assert drop > 0.3, "training did not learn"
    print("train_lm OK")


if __name__ == "__main__":
    main()
