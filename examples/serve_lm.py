"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b --batch 4
"""
import argparse

from repro.launch.lm_serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    gen = serve_main([
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", "24", "--gen", str(args.gen),
    ])
    assert gen.shape == (args.batch, args.gen)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
