"""End-to-end driver: 3D volume reconstruction with the full substrate.

Demonstrates the paper's workload end to end: phantom volume ->
measurement simulation with noise -> distributed partition plan ->
mixed-precision hierarchical-communication CG with minibatch pipelining
-> checkpointed solver state (restart mid-solve) -> quality report.

    PYTHONPATH=src python examples/reconstruct_3d.py [--n 64] [--slices 16]

With ``--stream`` the same pipeline runs *out of core* (repro.stream):
the sinogram is simulated slab-by-slab into an on-disk store, the solve
drains budget-sized slabs (prefetching the next slab while the current
one solves), gets "preempted" mid-run, and resumes from the ckpt-backed
slab manifest -- the volume never materializes in host memory.
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.ckpt.checkpoint import latest_step, restore, save
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices, simulate_measurements


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--angles", type=int, default=96)
    ap.add_argument("--slices", type=int, default=16)
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--noise", type=float, default=0.02)
    ap.add_argument("--stream", action="store_true",
                    help="out-of-core slab streaming + preempt/resume")
    args = ap.parse_args(argv)

    t0 = time.time()
    geo = XCTGeometry(n=args.n, n_angles=args.angles)
    print(f"[1/4] system matrix: {geo.n_rays} rays x {geo.n_vox} voxels")
    a = build_system_matrix(geo)
    plan = build_plan(
        geo, PartitionConfig(n_data=1, tile=8, rows_per_block=32,
                             nnz_per_stage=32), a=a,
    )
    print(f"      nnz={a.nnz/1e6:.1f}M  built in {time.time()-t0:.1f}s")

    if args.stream:
        return _main_streaming(args, geo, a, plan)

    print(f"[2/4] simulating {args.slices}-slice measurement "
          f"(noise {args.noise:.0%})")
    x_true = phantom_slices(geo.n, args.slices)
    sino = simulate_measurements(a, x_true, noise=args.noise)

    print("[3/4] reconstructing (mixed precision, hierarchical comm, "
          "pipelined minibatches)")
    rec = Reconstructor(
        plan,
        cfg=ReconConfig(precision="mixed", comm_mode="hier", fuse=4,
                        overlap=True),
    )
    # run the first half, checkpoint, then resume -- proving solver-state
    # restart (what a preempted pod would do)
    half = args.iters // 2
    t1 = time.time()
    x_half, res1 = rec.reconstruct(sino, iters=half)
    ckdir = tempfile.mkdtemp(prefix="xct_ck_")
    save(ckdir, half, {"x": x_half, "res": res1})
    state = restore(
        ckdir, latest_step(ckdir),
        {"x": np.zeros_like(x_half), "res": np.zeros_like(res1)},
    )
    x, res2 = rec.reconstruct(sino, iters=args.iters - half,
                              x0_nat=state["x"])
    dt = time.time() - t1

    rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
        x_true, axis=0
    )
    print(f"[4/4] {args.iters} CG iters (restarted at {half}) "
          f"in {dt:.1f}s")
    print(f"      rel err mean {rel.mean():.4f}  "
          f"residual {res1[0,0]:.3e} -> {res2[-1,0]:.3e}")
    assert rel.mean() < 0.3
    print("reconstruct_3d OK")


def _main_streaming(args, geo, a, plan):
    from repro.stream import (
        SlabStore, reconstruct_streaming, simulate_to_store, suggest_slab,
    )

    rec = Reconstructor(
        plan,
        cfg=ReconConfig(precision="mixed", comm_mode="hier", fuse=4,
                        overlap=True),
    )
    wd = tempfile.mkdtemp(prefix="xct_stream_")
    granule = rec.n_batch * rec.cfg.fuse
    print(f"[2/4] simulating {args.slices} slices slab-by-slab into "
          f"{wd}/sino (noise {args.noise:.0%})")
    sino = SlabStore.create(
        os.path.join(wd, "sino"), geo.n_rays, args.slices, granule
    )
    simulate_to_store(a, geo.n, sino, noise=args.noise, seed=0)

    # budget: operator + ~2 granules of working set -> several slabs
    sp = suggest_slab(plan, rec.cfg, rec.topology, 1 << 40)
    budget = sp.fixed_bytes + 2 * granule * sp.per_slice_bytes
    print(f"[3/4] streaming solve under a {budget / 2**20:.1f} MiB "
          "budget, preempted after one slab, then resumed")
    t1 = time.time()
    ck = os.path.join(wd, "ckpt")
    part = reconstruct_streaming(
        rec, sino, os.path.join(wd, "vol"), iters=args.iters,
        mem_budget=budget, ckpt_dir=ck, checkpoint_every=1, max_slabs=1,
    )
    rest = reconstruct_streaming(
        rec, sino, os.path.join(wd, "vol"), iters=args.iters,
        mem_budget=budget, ckpt_dir=ck,
    )
    dt = time.time() - t1
    assert rest.skipped == part.solved and rest.complete

    # slab-wise QA -- the full volume never lives in host memory
    errs = []
    for j0, j1 in rest.volume.slabs():
        x_true = phantom_slices(geo.n, args.slices, start=j0, stop=j1)
        x = rest.volume.read(j0, j1)
        errs.append(np.linalg.norm(x - x_true, axis=0)
                    / np.linalg.norm(x_true, axis=0))
    rel = np.concatenate(errs)
    n_slabs = len(part.solved) + len(rest.solved)
    print(f"[4/4] {args.slices} slices in {n_slabs} slab(s) of "
          f"{rest.y_slab} in {dt:.1f}s (resume skipped "
          f"{len(rest.skipped)})")
    print(f"      rel err mean {rel.mean():.4f}")
    assert rel.mean() < 0.3
    print("reconstruct_3d --stream OK")


if __name__ == "__main__":
    main()
