"""Quickstart: reconstruct a phantom in ~30 lines with the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices, simulate_measurements


def main():
    # 1. scan geometry (one slice; all slices share the system matrix)
    geo = XCTGeometry(n=48, n_angles=72)
    a = build_system_matrix(geo)

    # 2. partition plan: 1 device here; same code scales to a pod
    plan = build_plan(geo, PartitionConfig(n_data=1))

    # 3. simulate a measurement of an 8-slice phantom
    x_true = phantom_slices(geo.n, 8)
    sino = simulate_measurements(a, x_true, noise=0.01)

    # 4. reconstruct with the paper's mixed-precision + hierarchical comm
    rec = Reconstructor(
        plan,
        cfg=ReconConfig(precision="mixed", comm_mode="hier", fuse=4),
    )
    x, residuals = rec.reconstruct(sino, iters=24)

    rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
        x_true, axis=0
    )
    print(f"relative error per slice: {np.round(rel, 3)}")
    print(f"residual: {residuals[0, 0]:.3e} -> {residuals[-1, 0]:.3e}")
    assert rel.mean() < 0.25
    print("quickstart OK")


if __name__ == "__main__":
    main()
