"""Data determinism + fault-tolerance invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.phantom import phantom_slices
from repro.data.tokens import TokenStream
from repro.dist.fault import (
    StragglerMonitor, rebalance, suggest_checkpoint_period,
)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]))
def test_tokens_deterministic(step, shards):
    s1 = TokenStream(512, 32, 8, seed=3, n_shards=shards)
    s2 = TokenStream(512, 32, 8, seed=3, n_shards=shards)
    b1, b2 = s1.batch(step), s2.batch(step)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])


def test_shard_recompute_equals_global():
    """Any worker can regenerate any shard: shard k of the global batch
    equals an independent shard_batch(step, k) call."""
    s = TokenStream(512, 16, 12, seed=1, n_shards=3)
    full = s.batch(7)["inputs"]
    for k in range(3):
        shard = s.shard_batch(7, k)["inputs"]
        np.testing.assert_array_equal(full[k * 4 : (k + 1) * 4], shard)


def test_tokens_are_learnable():
    """Markov structure: next-token entropy below uniform."""
    s = TokenStream(256, 128, 16, seed=0)
    b = s.batch(0)["inputs"]
    follow = (b[:, :-1] * 31 + 7) % max(8, 256 // 16)
    frac = (b[:, 1:] == follow).mean()
    assert frac > 0.5  # mostly predictable transitions


def test_phantom_in_range():
    x = phantom_slices(32, 4)
    assert x.shape == (1024, 4)
    assert (x >= 0).all() and x.max() <= 2.0
    assert x.max() > 0.1  # non-trivial content


def test_straggler_detection():
    m = StragglerMonitor(k_mad=4.0)
    for w in range(8):
        for _ in range(5):
            m.record(w, 1.0 + 0.01 * w)
    m.record(3, 30.0)  # worker 3 stalls
    assert m.stragglers() == [3]


def test_rebalance_conserves_slices():
    ranges = {0: (0, 100), 1: (100, 200), 2: (200, 300)}
    out = rebalance(ranges, stragglers=[1])
    total = sum(e - s for s, e in out.values())
    assert total == 300
    s1 = out[1]
    assert s1[1] - s1[0] < 100  # straggler sheds load


def test_rebalance_degenerate_cases():
    """Pinned regressions: empty input and full shed must not zero a
    worker out of the mesh (membership is ``remesh``'s job)."""
    assert rebalance({}, stragglers=[0]) == {}
    ranges = {0: (0, 10), 1: (10, 20)}
    out = rebalance(ranges, stragglers=[1], shed=1.0)
    assert out[1][1] - out[1][0] >= 1  # keeps at least one slice
    assert sum(e - s for s, e in out.values()) == 20
    # a worker that already had nothing stays empty, contiguity holds
    ranges = {0: (0, 10), 1: (10, 10), 2: (10, 30)}
    out = rebalance(ranges, stragglers=[2], shed=0.5)
    assert sum(e - s for s, e in out.values()) == 30
    assert out[0][1] == out[1][0] and out[1][1] == out[2][0]


@settings(max_examples=40, deadline=None)
@given(
    st.integers(0, 2**32 - 1),
    st.integers(2, 8),
    st.sampled_from([0.25, 0.5, 1.0]),
)
def test_rebalance_properties(seed, n_workers, shed):
    """Property pins: total-slice conservation, monotone contiguity,
    start preservation, and stragglers never *gaining* load -- for any
    layout (incl. empty per-worker ranges) and any shed fraction."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, 40, size=n_workers)
    start = int(rng.integers(0, 100))
    ranges, s = {}, start
    for w in range(n_workers):
        ranges[w] = (s, s + int(sizes[w]))
        s += int(sizes[w])
    stragglers = [
        w for w in range(n_workers) if rng.random() < 0.4
    ]
    before = {w: e - s0 for w, (s0, e) in ranges.items()}
    out = rebalance(ranges, stragglers, shed=shed)
    # conservation + same span start
    assert sum(e - s0 for s0, e in out.values()) == sum(before.values())
    assert min(s0 for s0, _ in out.values()) == start
    # contiguity: ranges tile the span in worker key order
    keys = sorted(out)
    for a, b in zip(keys, keys[1:]):
        assert out[a][1] == out[b][0]
    healthy = [w for w in range(n_workers) if w not in stragglers]
    if stragglers and healthy:
        for w in stragglers:
            after = out[w][1] - out[w][0]
            assert after <= before[w]  # never grows
            if before[w] >= 1:
                assert after >= 1  # never zeroed out


def test_checkpoint_period_scaling():
    """More nodes => shorter optimal period (Young/Daly)."""
    p1k = suggest_checkpoint_period(30, 1000)
    p4k = suggest_checkpoint_period(30, 4000)
    assert p4k < p1k < suggest_checkpoint_period(30, 10)
