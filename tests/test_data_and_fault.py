"""Data determinism + fault-tolerance invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.phantom import phantom_slices
from repro.data.tokens import TokenStream
from repro.dist.fault import (
    StragglerMonitor, rebalance, suggest_checkpoint_period,
)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]))
def test_tokens_deterministic(step, shards):
    s1 = TokenStream(512, 32, 8, seed=3, n_shards=shards)
    s2 = TokenStream(512, 32, 8, seed=3, n_shards=shards)
    b1, b2 = s1.batch(step), s2.batch(step)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])


def test_shard_recompute_equals_global():
    """Any worker can regenerate any shard: shard k of the global batch
    equals an independent shard_batch(step, k) call."""
    s = TokenStream(512, 16, 12, seed=1, n_shards=3)
    full = s.batch(7)["inputs"]
    for k in range(3):
        shard = s.shard_batch(7, k)["inputs"]
        np.testing.assert_array_equal(full[k * 4 : (k + 1) * 4], shard)


def test_tokens_are_learnable():
    """Markov structure: next-token entropy below uniform."""
    s = TokenStream(256, 128, 16, seed=0)
    b = s.batch(0)["inputs"]
    follow = (b[:, :-1] * 31 + 7) % max(8, 256 // 16)
    frac = (b[:, 1:] == follow).mean()
    assert frac > 0.5  # mostly predictable transitions


def test_phantom_in_range():
    x = phantom_slices(32, 4)
    assert x.shape == (1024, 4)
    assert (x >= 0).all() and x.max() <= 2.0
    assert x.max() > 0.1  # non-trivial content


def test_straggler_detection():
    m = StragglerMonitor(k_mad=4.0)
    for w in range(8):
        for _ in range(5):
            m.record(w, 1.0 + 0.01 * w)
    m.record(3, 30.0)  # worker 3 stalls
    assert m.stragglers() == [3]


def test_rebalance_conserves_slices():
    ranges = {0: (0, 100), 1: (100, 200), 2: (200, 300)}
    out = rebalance(ranges, stragglers=[1])
    total = sum(e - s for s, e in out.values())
    assert total == 300
    s1 = out[1]
    assert s1[1] - s1[0] < 100  # straggler sheds load


def test_checkpoint_period_scaling():
    """More nodes => shorter optimal period (Young/Daly)."""
    p1k = suggest_checkpoint_period(30, 1000)
    p4k = suggest_checkpoint_period(30, 4000)
    assert p4k < p1k < suggest_checkpoint_period(30, 10)
