"""Import sweep: every module under ``src/repro`` must import.

A missing submodule (e.g. the ``repro.dist`` package absent from the
seed) used to surface only as a collection error of whichever test
happened to import it first; this sweep pins the failure to the module
itself.
"""
import importlib
import os
import pkgutil

import pytest

import repro


def _iter_modules():
    return sorted(
        m.name
        for m in pkgutil.walk_packages(repro.__path__, prefix="repro.")
    )


MODULES = _iter_modules()


def test_sweep_is_nonempty():
    # Guard the walker itself: a packaging regression that hides the
    # tree would otherwise pass the sweep vacuously.
    assert len(MODULES) > 30, MODULES
    assert "repro.dist.collectives" in MODULES


@pytest.mark.parametrize("name", MODULES)
def test_module_imports(name):
    # launch.dryrun mutates XLA_FLAGS at import (it wants 512 fake
    # devices before jax init); importing it here is safe because jax is
    # already initialized, but keep the env clean for later tests.
    before = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(name)
    finally:
        if os.environ.get("XLA_FLAGS") != before:
            if before is None:
                os.environ.pop("XLA_FLAGS", None)
            else:
                os.environ["XLA_FLAGS"] = before
