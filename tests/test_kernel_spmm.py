"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import apply_operator
from repro.kernels.ref import spmm_ref
from repro.kernels.xct_spmm import spmm_block_ell, vmem_bytes


def _random_ell(rng, b, s, r, k, buf, c, f):
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = (rng.random((b, s, r, k)) * (rng.random((b, s, r, k)) > 0.3)
            ).astype(np.float32)
    winmap = rng.integers(0, c, size=(b, s, buf)).astype(np.int32)
    x = rng.normal(size=(c, f)).astype(np.float32)
    return inds, vals, winmap, x


SWEEP = [
    # (B, S, R, K, BUF, C, F)
    (1, 1, 8, 8, 16, 64, 1),
    (2, 2, 16, 8, 32, 128, 4),
    (3, 1, 32, 16, 64, 256, 8),
    (2, 3, 8, 32, 40, 96, 16),
    (5, 2, 16, 16, 24, 64, 2),
]


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize(
    "storage", [jnp.float32, jnp.float16, jnp.bfloat16]
)
def test_kernel_matches_oracle(shape, storage):
    b, s, r, k, buf, c, f = shape
    rng = np.random.default_rng(hash((shape, str(storage))) % 2**31)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    vals_s = jnp.asarray(vals).astype(storage)
    x_s = jnp.asarray(x).astype(storage)
    window = jnp.take(x_s, jnp.asarray(winmap), axis=0)
    out = spmm_block_ell(
        jnp.asarray(inds), vals_s, window, compute_dtype=jnp.float32
    )
    ref = spmm_ref(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=jnp.float32,
    )
    tol = 1e-5 if storage == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=tol, atol=tol,
    )


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 4), st.integers(1, 3), st.sampled_from([8, 16]),
    st.sampled_from([8, 16]), st.integers(1, 8), st.integers(0, 10_000),
)
def test_kernel_matches_oracle_hypothesis(b, s, r, k, f, seed):
    buf, c = 3 * k, 64
    rng = np.random.default_rng(seed)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    window = jnp.take(jnp.asarray(x), jnp.asarray(winmap), axis=0)
    out = spmm_block_ell(jnp.asarray(inds), jnp.asarray(vals), window)
    ref = spmm_ref(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x),
    )
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=1e-5, atol=1e-5,
    )


def test_apply_operator_chunked_equals_unchunked():
    rng = np.random.default_rng(7)
    b, s, r, k, buf, c, f = 8, 2, 16, 8, 32, 128, 4
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    full = apply_operator(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x), storage_dtype=jnp.float32, blocks_per_call=8,
    )
    chunked = apply_operator(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x), storage_dtype=jnp.float32, blocks_per_call=2,
    )
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=1e-6
    )


def test_ref_flag_equals_kernel():
    rng = np.random.default_rng(9)
    b, s, r, k, buf, c, f = 4, 2, 16, 16, 48, 96, 8
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    a = apply_operator(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x), storage_dtype=jnp.float16, use_ref=False,
    )
    b_ = apply_operator(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x), storage_dtype=jnp.float16, use_ref=True,
    )
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b_), rtol=2e-2, atol=2e-2
    )


def test_vmem_budget_within_v5e():
    """Default production tile must fit the ~96KB-class VMEM budget the
    paper's shared-memory staging targets (and far below real VMEM)."""
    assert vmem_bytes(64, 64, 768, 16) < 1 << 20
