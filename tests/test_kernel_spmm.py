"""Pallas kernel vs pure-jnp oracle: fused in-kernel staging vs the
legacy gather baseline, shape/dtype sweeps, property tests, and the
no-staged-window jaxpr pin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import (
    apply_operator,
    dma_issue_count,
    segment_histogram,
    sort_segments_by_class,
    winmap_segments,
)
from repro.kernels.ref import spmm_ref
from repro.kernels.traffic import est_segments_per_stage, spmm_traffic
from repro.kernels.xct_spmm import (
    seg_smem_bytes,
    smem_bytes,
    spmm_block_ell,
    spmm_block_ell_staged,
    vmem_bytes,
)


def _seed(*parts) -> int:
    """Stable cross-process seed (hash() of str is salted per run)."""
    import zlib

    return zlib.crc32(repr(parts).encode())


def _random_ell(rng, b, s, r, k, buf, c, f):
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = (rng.random((b, s, r, k)) * (rng.random((b, s, r, k)) > 0.3)
            ).astype(np.float32)
    winmap = rng.integers(0, c, size=(b, s, buf)).astype(np.int32)
    x = rng.normal(size=(c, f)).astype(np.float32)
    return inds, vals, winmap, x


SWEEP = [
    # (B, S, R, K, BUF, C, F) -- deliberately includes non-divisible
    # B/S combinations (3, 5) and non-power-of-two BUF
    (1, 1, 8, 8, 16, 64, 1),
    (2, 2, 16, 8, 32, 128, 4),
    (3, 1, 32, 16, 64, 256, 8),
    (2, 3, 8, 32, 40, 96, 16),
    (5, 2, 16, 16, 24, 64, 2),
]


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize(
    "storage", [jnp.float32, jnp.float16, jnp.bfloat16]
)
def test_fused_kernel_matches_oracle(shape, storage):
    """The in-kernel-staging path against the unstaged-interface oracle."""
    b, s, r, k, buf, c, f = shape
    rng = np.random.default_rng(_seed(shape, storage))
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    vals_s = jnp.asarray(vals).astype(storage)
    x_s = jnp.asarray(x).astype(storage)
    out = spmm_block_ell(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=jnp.float32,
    )
    ref = spmm_ref(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=jnp.float32,
    )
    tol = 1e-5 if storage == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("shape", SWEEP[:3])
def test_staged_kernel_matches_oracle(shape):
    """The legacy pre-staged-window kernel stays correct (A/B baseline)."""
    b, s, r, k, buf, c, f = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    window = jnp.take(jnp.asarray(x), jnp.asarray(winmap), axis=0)
    out = spmm_block_ell_staged(
        jnp.asarray(inds), jnp.asarray(vals), window
    )
    ref = spmm_ref(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x),
    )
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=1e-5, atol=1e-5,
    )


# property-style sweep (real hypothesis when installed, deterministic
# shim otherwise): fused staging across the precision ladder x shapes,
# including B/S the grid does not divide evenly into anything
@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 5), st.integers(1, 3), st.sampled_from([8, 16]),
    st.sampled_from([8, 16]), st.integers(1, 8),
    st.sampled_from(["f32", "f16", "bf16"]),
    st.sampled_from(["f32", "f16"]),
    st.integers(0, 10_000),
)
def test_fused_matches_oracle_hypothesis(
    b, s, r, k, f, storage, compute, seed
):
    sdt = {"f32": jnp.float32, "f16": jnp.float16,
           "bf16": jnp.bfloat16}[storage]
    cdt = {"f32": jnp.float32, "f16": jnp.float16}[compute]
    buf, c = 3 * k, 64
    rng = np.random.default_rng(seed)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    vals_s = jnp.asarray(vals).astype(sdt)
    x_s = jnp.asarray(x).astype(sdt)
    out = spmm_block_ell(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=cdt,
    )
    ref = spmm_ref(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=cdt,
    )
    wide = sdt == jnp.float32 and cdt == jnp.float32
    tol = 1e-5 if wide else 5e-2
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f),
        np.asarray(ref).astype(np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("storage", [jnp.float32, jnp.float16])
def test_fused_equals_gather_equals_oracle(storage):
    """The three apply_operator paths agree within mixed tolerance."""
    rng = np.random.default_rng(9)
    b, s, r, k, buf, c, f = 4, 2, 16, 16, 48, 96, 8
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    args = tuple(
        jnp.asarray(v) for v in (inds, vals, winmap, x)
    )
    outs = {
        name: np.asarray(
            apply_operator(*args, storage_dtype=storage, **kw)
        )
        for name, kw in (
            ("fused", {}),
            ("gather", {"staging": "gather"}),
            ("oracle", {"use_ref": True}),
        )
    }
    tol = 1e-5 if storage == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        outs["fused"], outs["gather"], rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        outs["fused"], outs["oracle"], rtol=tol, atol=tol
    )


def test_gather_chunked_equals_unchunked():
    rng = np.random.default_rng(7)
    b, s, r, k, buf, c, f = 8, 2, 16, 8, 32, 128, 4
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    args = tuple(jnp.asarray(v) for v in (inds, vals, winmap, x))
    full = apply_operator(
        *args, storage_dtype=jnp.float32, staging="gather",
        blocks_per_call=8,
    )
    chunked = apply_operator(
        *args, storage_dtype=jnp.float32, staging="gather",
        blocks_per_call=2,
    )
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=1e-6
    )


def _walk_avals(jaxpr, shapes):
    """Collect every intermediate/output aval shape in a jaxpr tree."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval"):
                shapes.add(tuple(getattr(v.aval, "shape", ())))
        for p in eqn.params.values():
            for sub in jax.tree.leaves(
                p, is_leaf=lambda x: hasattr(x, "eqns")
            ):
                if hasattr(sub, "eqns"):
                    _walk_avals(sub, shapes)
                elif hasattr(sub, "jaxpr"):
                    _walk_avals(sub.jaxpr, shapes)
    return shapes


def _window_shapes(staging):
    b, s, r, k, buf, c, f = 4, 2, 16, 16, 48, 96, 8
    rng = np.random.default_rng(3)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    segs = winmap_segments(winmap)  # traced winmap cannot be RLE'd

    def fn(i, v, w, xx):
        return apply_operator(
            i, v, w, xx, storage_dtype=jnp.float16, staging=staging,
            winsegs=segs,
        )

    jaxpr = jax.make_jaxpr(fn)(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x),
    )
    shapes = _walk_avals(jaxpr.jaxpr, set())
    # any intermediate carrying a [*, S, BUF, F] window tensor (the scan
    # -chunked gather stages [bpc, S, BUF, F] blocks)
    return {
        sh for sh in shapes
        if len(sh) == 4 and sh[1:] == (s, buf, f)
    }


def test_fused_jaxpr_has_no_staged_window():
    """Acceptance pin: the default path's jaxpr materializes no
    [B, S, BUF, F] window tensor anywhere (the gather baseline does)."""
    assert _window_shapes("fused") == set()
    assert _window_shapes("gather") != set()


def test_winmap_smem_budget_at_suite_scale(small_system):
    """The fused kernel scalar-prefetches the *whole* [B, S, BUF] winmap
    to SMEM (unlike the per-step VMEM working set).  Pin that the shards
    this suite and the quick bench actually run stay deep inside scalar
    memory; production-B shards need the prefetch chunked first (see
    smem_bytes docstring + ROADMAP on-TPU item)."""
    _, _, plan = small_system
    for op in (plan.proj, plan.back):
        _, b, s, _, _ = op.inds.shape
        assert smem_bytes(b, s, op.winmap.shape[-1]) < 256 << 10, (
            op.winmap.shape
        )


def test_vmem_budget_within_paper_shared_memory():
    """The double-buffered production tile (R=64, K=64, BUF=768, F=16,
    2-byte storage) must fit the ~96 KB-class shared-memory budget the
    paper's multi-stage buffering targets (and far below real VMEM)."""
    assert vmem_bytes(64, 64, 768, 16) < 96 << 10
    # single-slot legacy footprint is smaller still
    assert vmem_bytes(64, 64, 768, 16, stages_buffered=1) < vmem_bytes(
        64, 64, 768, 16
    )


# --------------------------------------------------------------------- #
# run-length coalesced window DMAs (ISSUE 5 tentpole)
# --------------------------------------------------------------------- #
def _winmap_from_runs(rng, buf, c, run_lo, run_hi):
    """A window made of random-length runs of consecutive source rows."""
    row = []
    while len(row) < buf:
        st = int(rng.integers(0, max(1, c - run_hi)))
        ln = int(rng.integers(run_lo, run_hi + 1))
        row.extend(range(st, st + min(ln, buf - len(row))))
    return np.asarray(row[:buf], np.int32)


def test_winmap_segments_known():
    """Exact RLE + binary decomposition on a hand-written winmap, and
    the issue count the kernel will pay (acceptance pin: one DMA per
    run-length segment)."""
    # runs: [5..9] (len 5 -> 4+1), [20] (1), [9,10,11] (len 3 -> 2+1)
    wm = np.array([[[5, 6, 7, 8, 9, 20, 9, 10, 11]]], np.int32)
    segs = winmap_segments(wm)
    want = [
        (5, 0, 4), (9, 4, 1),  # run of 5, largest-first decomposition
        (20, 5, 1),
        (9, 6, 2), (11, 8, 1),  # run of 3
    ]
    got = [tuple(t) for t in segs[0, 0] if t[2] > 0]
    assert got == want
    assert dma_issue_count(segs) == 5  # vs 9 per-row copies
    assert segment_histogram(segs) == {1: 3, 2: 1, 4: 1}
    # pad slots are len == 0 and the capacity is padded to 8
    assert segs.shape[-2] % 8 == 0
    assert (segs[0, 0, 5:, 2] == 0).all()


def test_winmap_segments_tile_window():
    """Property: the dst ranges of a table tile [0, BUF) exactly and
    replay the winmap -- so the coalesced copies deliver bit-identical
    window contents to the per-row path, for ANY winmap."""
    rng = np.random.default_rng(11)
    for trial in range(5):
        buf, c = 64, 256
        wm = _winmap_from_runs(rng, buf, c, 1, 9)
        segs = winmap_segments(wm[None, None])[0, 0]
        rebuilt = np.full(buf, -1, np.int64)
        covered = np.zeros(buf, bool)
        for src, dst, ln in segs:
            if ln == 0:
                continue
            assert not covered[dst:dst + ln].any()  # no overlap
            covered[dst:dst + ln] = True
            rebuilt[dst:dst + ln] = np.arange(src, src + ln)
        assert covered.all()  # no hole
        np.testing.assert_array_equal(rebuilt, wm)


ADVERSARIAL = {
    # every run length 1 (worst case: coalescing degenerates to per-row)
    "single-row-runs": lambda rng, buf, c: rng.permutation(
        np.arange(0, 2 * buf, 2)[:buf]
    ).astype(np.int32),
    # one full-window run (best case: a single strided copy chain)
    "one-full-run": lambda rng, buf, c: (
        np.arange(buf, dtype=np.int32) + int(rng.integers(0, c - buf))
    ),
    # shuffled Hilbert order: consecutive chunks, random order + lengths
    "shuffled-hilbert": lambda rng, buf, c: _winmap_from_runs(
        rng, buf, c, 1, 13
    ),
}


@pytest.mark.parametrize("kind", sorted(ADVERSARIAL))
@pytest.mark.parametrize(
    "storage,compute",
    [
        (jnp.float32, jnp.float32),
        (jnp.float16, jnp.float32),
        (jnp.bfloat16, jnp.float32),
        (jnp.float16, jnp.float16),
    ],
)
def test_coalesced_bitexact_vs_per_row(kind, storage, compute):
    """Acceptance pin: coalesced and per-row DMA paths are BIT-exact
    across the storage x compute ladder on adversarial winmaps, and
    the issue count is never worse than per-row."""
    rng = np.random.default_rng(_seed(kind, storage, compute))
    b, s, r, k, buf, c, f = 3, 2, 16, 8, 40, 128, 4  # ragged B/S
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = rng.random((b, s, r, k)).astype(np.float32)
    wm = np.stack([
        np.stack([ADVERSARIAL[kind](rng, buf, c) for _ in range(s)])
        for _ in range(b)
    ])
    x = rng.normal(size=(c, f)).astype(np.float32)
    args = tuple(jnp.asarray(v) for v in (inds, vals, wm, x))
    out = {
        dma: np.asarray(apply_operator(
            *args, storage_dtype=storage, compute_dtype=compute,
            dma=dma,
        ))
        for dma in ("coalesced", "per_row")
    }
    np.testing.assert_array_equal(out["coalesced"], out["per_row"])
    issues = dma_issue_count(winmap_segments(wm))
    assert issues <= b * s * buf
    if kind == "one-full-run":
        # BUF=40 = 32+8: two copies per stage instead of 40
        assert issues == 2 * b * s


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 5), st.integers(1, 3), st.sampled_from([8, 16]),
    st.integers(1, 6), st.integers(1, 16),
    st.sampled_from(["f32", "f16", "bf16"]),
    st.sampled_from(["f32", "f16"]),
    st.integers(0, 10_000),
)
def test_coalesced_property_sweep(b, s, r, f, run_hi, storage, compute,
                                  seed):
    """Property sweep (satellite): coalesced == per-row bit-exact for
    random run mixtures across dtypes and ragged (non-divisible) B/S."""
    sdt = {"f32": jnp.float32, "f16": jnp.float16,
           "bf16": jnp.bfloat16}[storage]
    cdt = {"f32": jnp.float32, "f16": jnp.float16}[compute]
    k, buf, c = 8, 24, 96
    rng = np.random.default_rng(seed)
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = rng.random((b, s, r, k)).astype(np.float32)
    wm = np.stack([
        np.stack([
            _winmap_from_runs(rng, buf, c, 1, run_hi) for _ in range(s)
        ])
        for _ in range(b)
    ])
    x = rng.normal(size=(c, f)).astype(np.float32)
    args = tuple(jnp.asarray(v) for v in (inds, vals, wm, x))
    out = {
        dma: np.asarray(apply_operator(
            *args, storage_dtype=sdt, compute_dtype=cdt, dma=dma,
        ))
        for dma in ("coalesced", "per_row")
    }
    np.testing.assert_array_equal(out["coalesced"], out["per_row"])


@pytest.mark.parametrize("dma", ["coalesced", "per_row"])
def test_chunked_prefetch_matches_single_shot(dma):
    """Acceptance pin: a shard whose B overflows the single-shot SMEM
    budget runs correctly -- the outer scan over row-block chunks is
    bit-exact vs the unchunked call."""
    rng = np.random.default_rng(23)
    b, s, r, k, buf, c, f = 8, 2, 8, 8, 16, 64, 4
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = rng.random((b, s, r, k)).astype(np.float32)
    wm = np.stack([
        np.stack([_winmap_from_runs(rng, buf, c, 1, 5)
                  for _ in range(s)])
        for _ in range(b)
    ])
    x = rng.normal(size=(c, f)).astype(np.float32)
    args = tuple(jnp.asarray(v) for v in (inds, vals, wm, x))
    full = apply_operator(*args, storage_dtype=jnp.float32, dma=dma)
    # budget fits ~2 row-blocks of descriptors -> 4 scan chunks
    nseg = winmap_segments(wm).shape[-2]
    budget = (
        seg_smem_bytes(2, s, nseg)
        if dma == "coalesced"
        else smem_bytes(2, s, buf)
    )
    assert budget < (smem_bytes(b, s, buf) if dma == "per_row"
                     else seg_smem_bytes(b, s, nseg))
    chunked = apply_operator(
        *args, storage_dtype=jnp.float32, dma=dma, smem_budget=budget
    )
    np.testing.assert_array_equal(np.asarray(full), np.asarray(chunked))


@pytest.mark.parametrize("dma", ["coalesced", "per_row"])
@pytest.mark.parametrize("chunked", [False, True])
def test_quantized_kernel_matches_dequantized_reference(dma, chunked):
    """Tentpole pin (ISSUE 8): int8 vals + per-block scales through the
    fused kernel -- the scales ride scalar prefetch and are applied
    inline in the FMA loop -- are BIT-exact vs running the same kernel
    on eagerly dequantized f32 vals, on every DMA/chunking path.
    (Power-of-two scales in f32 compute make dequant exact, so any
    difference is a kernel wiring bug, not rounding.)"""
    from repro.core.precision import (
        dequantize_block_vals,
        quantize_block_vals,
    )

    rng = np.random.default_rng(_seed("q8", dma, chunked))
    b, s, r, k, buf, c, f = 6, 2, 8, 8, 16, 64, 4
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    # spread block magnitudes over ~12 octaves so per-block scaling
    # actually varies (a single global scale would also pass otherwise)
    vals = (
        rng.random((b, s, r, k))
        * np.exp2(rng.integers(-6, 7, size=(b, s, 1, 1)))
    ).astype(np.float32)
    wm = np.stack([
        np.stack([_winmap_from_runs(rng, buf, c, 1, 5)
                  for _ in range(s)])
        for _ in range(b)
    ])
    x = rng.normal(size=(c, f)).astype(np.float32)
    q, exp = quantize_block_vals(jnp.asarray(vals), jnp.int8)
    wide = dequantize_block_vals(q, exp, jnp.float32)
    kw = dict(storage_dtype=jnp.float16, compute_dtype=jnp.float32,
              dma=dma)
    if chunked:
        nseg = winmap_segments(wm).shape[-2]
        kw["smem_budget"] = (
            seg_smem_bytes(2, s, nseg) if dma == "coalesced"
            else smem_bytes(2, s, buf)
        )
    args = (jnp.asarray(inds), jnp.asarray(wm), jnp.asarray(x))
    out_q = apply_operator(args[0], q, args[1], args[2],
                           scales=exp, **kw)
    # reference path: f32 storage so the dequantized vals pass through
    # the kernel unrounded; x pre-cast to the quantized path's f16
    # window values (f16 -> f32 is exact) so vals are the ONLY delta
    kw["storage_dtype"] = jnp.float32
    out_ref = apply_operator(
        args[0], wide, args[1], args[2].astype(jnp.float16), **kw
    )
    np.testing.assert_array_equal(
        np.asarray(out_q), np.asarray(out_ref)
    )
    # the oracle path dequantizes eagerly and must agree too
    out_oracle = apply_operator(
        args[0], q, args[1], args[2], scales=exp,
        storage_dtype=jnp.float16, compute_dtype=jnp.float32,
        use_ref=True,
    )
    np.testing.assert_allclose(
        np.asarray(out_q), np.asarray(out_oracle), rtol=1e-6, atol=1e-6
    )


def test_budget_guards_name_offending_dimension():
    """Satellite: over-budget blocks raise a named ValueError instead of
    sizing silently (Mosaic would fail opaquely)."""
    with pytest.raises(ValueError, match="BUF"):
        smem_bytes(1, 4, 512, budget=64)
    with pytest.raises(ValueError, match="NSEG"):
        seg_smem_bytes(1, 4, 512, budget=64)
    with pytest.raises(ValueError, match="window slots"):
        vmem_bytes(64, 64, 768, 16, budget=8 << 10)
    # end to end: a kernel call whose single row-block overflows
    rng = np.random.default_rng(3)
    b, s, r, k, buf, c, f = 1, 1, 8, 8, 16, 64, 2
    inds, vals, wm, x = _random_ell(rng, b, s, r, k, buf, c, f)
    with pytest.raises(ValueError, match="SMEM"):
        apply_operator(
            jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(wm),
            jnp.asarray(x), storage_dtype=jnp.float32, dma="per_row",
            smem_budget=16,
        )


def test_traffic_dma_issue_model():
    """The traffic model's issue term: coalesced < per-row strictly,
    measured segment counts plug in, and the gather baseline is priced
    as bulk tiles."""
    per = spmm_traffic(8, 2, 64, 64, 768, 16, dma="per_row")
    coal = spmm_traffic(8, 2, 64, 64, 768, 16, dma="coalesced")
    meas = spmm_traffic(
        8, 2, 64, 64, 768, 16, dma="coalesced", segments_per_stage=37
    )
    gath = spmm_traffic(8, 2, 64, 64, 768, 16, staging="gather")
    assert per["dma_issues"] == 8 * 2 * 768
    assert coal["dma_issues"] < per["dma_issues"]
    assert meas["dma_issues"] == 8 * 2 * 37
    assert gath["dma_issues"] == 8 * 2
    # descriptor bytes are priced per mode: 4 B/winmap row vs
    # 12 B/segment -- the small byte premium coalescing pays for the
    # big issue-count cut (window/operator terms are mode-invariant)
    assert per["winmap_bytes"] == 8 * 2 * 768 * 4
    assert meas["winmap_bytes"] == 8 * 2 * 37 * 12
    assert coal["window_bytes"] == per["window_bytes"]
    assert coal["operator_bytes"] == per["operator_bytes"]


def test_est_segments_calibrated(small_system):
    """The analytic segments-per-stage model tracks the measured
    ``winmap_segments`` tables of real plans (est/real in [0.5, 2] --
    the same calibration discipline as ``estimate_plan``)."""
    _, _, plan = small_system
    for op in (plan.proj, plan.back):
        buf = op.winmap.shape[-1]
        per_stage = (op.winsegs[..., 2] > 0).sum(axis=-1)
        real = float(per_stage.mean())
        est = est_segments_per_stage(buf)
        assert 0.5 <= est / max(real, 1.0) <= 2.0, (buf, real, est)


def test_plan_winsegs_replay_winmap(small_system):
    """The shard-attached tables (built by core.partition) replay every
    device's winmap exactly -- same property as the unit test above but
    on the real Hilbert-ordered operators the suite solves with."""
    _, _, plan = small_system
    op = plan.back
    p, b_, s_, buf = op.winmap.shape
    segs = op.winsegs
    for pi in (0,):
        for bi in range(min(2, b_)):
            for si in range(s_):
                rebuilt = np.full(buf, -1, np.int64)
                for src, dst, ln in segs[pi, bi, si]:
                    if ln:
                        rebuilt[dst:dst + ln] = np.arange(src, src + ln)
                np.testing.assert_array_equal(
                    rebuilt, op.winmap[pi, bi, si]
                )


# --------------------------------------------------------------------- #
# slot reordering (ISSUE 7): layout permutation invariance + the
# class-sorted segment tables the reordered kernel consumes
# --------------------------------------------------------------------- #
def _permute_layout(rng, inds, winmap):
    """Rename every (b, s) window's slots by an independent random
    permutation: ``winmap'[j] = winmap[perm[j]]``, ``inds' =
    perm^-1[inds]`` -- the same-values-different-slots transform slot
    reordering applies at plan build."""
    b, s, buf = winmap.shape
    wm2 = np.empty_like(winmap)
    inds2 = np.empty_like(inds)
    for bi in range(b):
        for si in range(s):
            perm = rng.permutation(buf)
            inv = np.argsort(perm)
            wm2[bi, si] = winmap[bi, si][perm]
            inds2[bi, si] = inv[inds[bi, si]].astype(inds.dtype)
    return inds2, wm2


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 4), st.integers(1, 3), st.sampled_from([8, 16]),
    st.integers(1, 8),
    st.sampled_from(["f32", "f16", "bf16"]),
    st.sampled_from(["f32", "f16"]),
    st.sampled_from(["coalesced", "per_row"]),
    st.integers(0, 10_000),
)
def test_slot_permutation_bitexact(
    b, s, r, f, storage, compute, dma, seed
):
    """Tentpole property (ISSUE 7): a window-slot layout is a pure
    renaming.  For ANY per-stage slot permutation the kernel output is
    BIT-identical across the storage x compute ladder under both DMA
    modes -- each (row, k) slot still multiplies the same value pair,
    in the same stage, in the same order, so not even the FP rounding
    can move.  This is the invariance that lets ``core.partition``
    reorder slots for long runs without touching numerics."""
    sdt = {"f32": jnp.float32, "f16": jnp.float16,
           "bf16": jnp.bfloat16}[storage]
    cdt = {"f32": jnp.float32, "f16": jnp.float16}[compute]
    k, buf, c = 8, 24, 96
    rng = np.random.default_rng(seed)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    inds2, wm2 = _permute_layout(rng, inds, winmap)
    out = [
        np.asarray(apply_operator(
            jnp.asarray(i), jnp.asarray(vals), jnp.asarray(w),
            jnp.asarray(x), storage_dtype=sdt, compute_dtype=cdt,
            dma=dma,
        ))
        for i, w in ((inds, winmap), (inds2, wm2))
    ]
    np.testing.assert_array_equal(out[0], out[1])


@settings(max_examples=10, deadline=None)
@given(
    st.integers(4, 64), st.integers(1, 12), st.integers(0, 10_000)
)
def test_winmap_segments_roundtrip_property(buf, run_hi, seed):
    """Satellite property (ISSUE 7): for ANY winmap the run-length
    table covers every window row exactly once with power-of-two
    lengths and no overlaps, and the class-sorted table preserves the
    cover while its offsets bracket exact length classes -- the
    contract the sorted coalesced kernel's per-class loops rely on."""
    from repro.kernels.xct_spmm import _dma_classes

    rng = np.random.default_rng(seed)
    wm = _winmap_from_runs(rng, buf, 4 * buf, 1, run_hi)[None, None]
    segs = winmap_segments(wm)
    srt, off = sort_segments_by_class(segs, buf)
    for table in (segs, srt):
        covered = np.zeros(buf, bool)
        rebuilt = np.full(buf, -1, np.int64)
        for src, dst, ln in table[0, 0]:
            if ln == 0:
                continue
            assert ln & (ln - 1) == 0, ln  # power-of-two pieces only
            assert not covered[dst:dst + ln].any()  # no overlap
            covered[dst:dst + ln] = True
            rebuilt[dst:dst + ln] = np.arange(src, src + ln)
        assert covered.all()  # no hole: every row delivered once
        np.testing.assert_array_equal(rebuilt, wm[0, 0])
    lens = srt[0, 0, :, 2]
    assert (np.diff(lens) <= 0).all()  # descending by copy length
    classes = _dma_classes(buf)[::-1]
    o = off[0, 0]
    assert o.shape == (len(classes) + 1,)
    assert (np.diff(o) >= 0).all()
    for i, ln in enumerate(classes):
        assert (lens[o[i]:o[i + 1]] == ln).all(), (ln, o)
    assert (lens[o[-1]:] == 0).all()  # only pads past the last offset
    assert o[-1] == int((lens > 0).sum())


def test_sort_segments_by_class_known():
    """Exact sorted table + offsets on the hand-written winmap of
    ``test_winmap_segments_known`` (stable within a length class)."""
    wm = np.array([[[5, 6, 7, 8, 9, 20, 9, 10, 11]]], np.int32)
    srt, off = sort_segments_by_class(winmap_segments(wm), 9)
    want = [(5, 0, 4), (9, 6, 2), (9, 4, 1), (20, 5, 1), (11, 8, 1)]
    assert [tuple(t) for t in srt[0, 0] if t[2] > 0] == want
    # classes descending for BUF=9: 8, 4, 2, 1; no len-8 segment
    np.testing.assert_array_equal(off[0, 0], [0, 0, 1, 2, 5])


def test_sorted_segments_bitexact_and_validated(small_system):
    """The class-sorted table + offsets drive the kernel to the same
    bits as the unsorted table, and a segoff whose class axis does not
    match BUF raises a named error instead of corrupting copies."""
    _, _, plan = small_system
    op = plan.proj
    inds = jnp.asarray(op.inds[0])
    vals = jnp.asarray(op.vals[0])
    wm = jnp.asarray(op.winmap[0])
    x = jnp.asarray(
        np.random.default_rng(5).normal(
            size=(op.cols_per_dev, 4)
        ).astype(np.float32)
    )
    legacy = apply_operator(
        inds, vals, wm, x, winsegs=jnp.asarray(op.winsegs[0]),
        dma="coalesced",
    )
    sorted_ = apply_operator(
        inds, vals, wm, x, winsegs=jnp.asarray(op.winsegs[0]),
        segoff=jnp.asarray(op.segoff[0]), dma="coalesced",
    )
    np.testing.assert_array_equal(
        np.asarray(legacy), np.asarray(sorted_)
    )
    with pytest.raises(ValueError, match="segoff"):
        apply_operator(
            inds, vals, wm, x, winsegs=jnp.asarray(op.winsegs[0]),
            segoff=jnp.asarray(op.segoff[0][..., :2]), dma="coalesced",
        )
