"""Pallas kernel vs pure-jnp oracle: fused in-kernel staging vs the
legacy gather baseline, shape/dtype sweeps, property tests, and the
no-staged-window jaxpr pin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import apply_operator
from repro.kernels.ref import spmm_ref
from repro.kernels.xct_spmm import (
    smem_bytes,
    spmm_block_ell,
    spmm_block_ell_staged,
    vmem_bytes,
)


def _random_ell(rng, b, s, r, k, buf, c, f):
    inds = rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    vals = (rng.random((b, s, r, k)) * (rng.random((b, s, r, k)) > 0.3)
            ).astype(np.float32)
    winmap = rng.integers(0, c, size=(b, s, buf)).astype(np.int32)
    x = rng.normal(size=(c, f)).astype(np.float32)
    return inds, vals, winmap, x


SWEEP = [
    # (B, S, R, K, BUF, C, F) -- deliberately includes non-divisible
    # B/S combinations (3, 5) and non-power-of-two BUF
    (1, 1, 8, 8, 16, 64, 1),
    (2, 2, 16, 8, 32, 128, 4),
    (3, 1, 32, 16, 64, 256, 8),
    (2, 3, 8, 32, 40, 96, 16),
    (5, 2, 16, 16, 24, 64, 2),
]


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize(
    "storage", [jnp.float32, jnp.float16, jnp.bfloat16]
)
def test_fused_kernel_matches_oracle(shape, storage):
    """The in-kernel-staging path against the unstaged-interface oracle."""
    b, s, r, k, buf, c, f = shape
    rng = np.random.default_rng(hash((shape, str(storage))) % 2**31)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    vals_s = jnp.asarray(vals).astype(storage)
    x_s = jnp.asarray(x).astype(storage)
    out = spmm_block_ell(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=jnp.float32,
    )
    ref = spmm_ref(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=jnp.float32,
    )
    tol = 1e-5 if storage == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("shape", SWEEP[:3])
def test_staged_kernel_matches_oracle(shape):
    """The legacy pre-staged-window kernel stays correct (A/B baseline)."""
    b, s, r, k, buf, c, f = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    window = jnp.take(jnp.asarray(x), jnp.asarray(winmap), axis=0)
    out = spmm_block_ell_staged(
        jnp.asarray(inds), jnp.asarray(vals), window
    )
    ref = spmm_ref(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x),
    )
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f), np.asarray(ref),
        rtol=1e-5, atol=1e-5,
    )


# property-style sweep (real hypothesis when installed, deterministic
# shim otherwise): fused staging across the precision ladder x shapes,
# including B/S the grid does not divide evenly into anything
@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 5), st.integers(1, 3), st.sampled_from([8, 16]),
    st.sampled_from([8, 16]), st.integers(1, 8),
    st.sampled_from(["f32", "f16", "bf16"]),
    st.sampled_from(["f32", "f16"]),
    st.integers(0, 10_000),
)
def test_fused_matches_oracle_hypothesis(
    b, s, r, k, f, storage, compute, seed
):
    sdt = {"f32": jnp.float32, "f16": jnp.float16,
           "bf16": jnp.bfloat16}[storage]
    cdt = {"f32": jnp.float32, "f16": jnp.float16}[compute]
    buf, c = 3 * k, 64
    rng = np.random.default_rng(seed)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    vals_s = jnp.asarray(vals).astype(sdt)
    x_s = jnp.asarray(x).astype(sdt)
    out = spmm_block_ell(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=cdt,
    )
    ref = spmm_ref(
        jnp.asarray(inds), vals_s, jnp.asarray(winmap), x_s,
        compute_dtype=cdt,
    )
    wide = sdt == jnp.float32 and cdt == jnp.float32
    tol = 1e-5 if wide else 5e-2
    np.testing.assert_allclose(
        np.asarray(out).reshape(b * r, f),
        np.asarray(ref).astype(np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("storage", [jnp.float32, jnp.float16])
def test_fused_equals_gather_equals_oracle(storage):
    """The three apply_operator paths agree within mixed tolerance."""
    rng = np.random.default_rng(9)
    b, s, r, k, buf, c, f = 4, 2, 16, 16, 48, 96, 8
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    args = tuple(
        jnp.asarray(v) for v in (inds, vals, winmap, x)
    )
    outs = {
        name: np.asarray(
            apply_operator(*args, storage_dtype=storage, **kw)
        )
        for name, kw in (
            ("fused", {}),
            ("gather", {"staging": "gather"}),
            ("oracle", {"use_ref": True}),
        )
    }
    tol = 1e-5 if storage == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        outs["fused"], outs["gather"], rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        outs["fused"], outs["oracle"], rtol=tol, atol=tol
    )


def test_gather_chunked_equals_unchunked():
    rng = np.random.default_rng(7)
    b, s, r, k, buf, c, f = 8, 2, 16, 8, 32, 128, 4
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)
    args = tuple(jnp.asarray(v) for v in (inds, vals, winmap, x))
    full = apply_operator(
        *args, storage_dtype=jnp.float32, staging="gather",
        blocks_per_call=8,
    )
    chunked = apply_operator(
        *args, storage_dtype=jnp.float32, staging="gather",
        blocks_per_call=2,
    )
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=1e-6
    )


def _walk_avals(jaxpr, shapes):
    """Collect every intermediate/output aval shape in a jaxpr tree."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval"):
                shapes.add(tuple(getattr(v.aval, "shape", ())))
        for p in eqn.params.values():
            for sub in jax.tree.leaves(
                p, is_leaf=lambda x: hasattr(x, "eqns")
            ):
                if hasattr(sub, "eqns"):
                    _walk_avals(sub, shapes)
                elif hasattr(sub, "jaxpr"):
                    _walk_avals(sub.jaxpr, shapes)
    return shapes


def _window_shapes(staging):
    b, s, r, k, buf, c, f = 4, 2, 16, 16, 48, 96, 8
    rng = np.random.default_rng(3)
    inds, vals, winmap, x = _random_ell(rng, b, s, r, k, buf, c, f)

    def fn(i, v, w, xx):
        return apply_operator(
            i, v, w, xx, storage_dtype=jnp.float16, staging=staging
        )

    jaxpr = jax.make_jaxpr(fn)(
        jnp.asarray(inds), jnp.asarray(vals), jnp.asarray(winmap),
        jnp.asarray(x),
    )
    shapes = _walk_avals(jaxpr.jaxpr, set())
    # any intermediate carrying a [*, S, BUF, F] window tensor (the scan
    # -chunked gather stages [bpc, S, BUF, F] blocks)
    return {
        sh for sh in shapes
        if len(sh) == 4 and sh[1:] == (s, buf, f)
    }


def test_fused_jaxpr_has_no_staged_window():
    """Acceptance pin: the default path's jaxpr materializes no
    [B, S, BUF, F] window tensor anywhere (the gather baseline does)."""
    assert _window_shapes("fused") == set()
    assert _window_shapes("gather") != set()


def test_winmap_smem_budget_at_suite_scale(small_system):
    """The fused kernel scalar-prefetches the *whole* [B, S, BUF] winmap
    to SMEM (unlike the per-step VMEM working set).  Pin that the shards
    this suite and the quick bench actually run stay deep inside scalar
    memory; production-B shards need the prefetch chunked first (see
    smem_bytes docstring + ROADMAP on-TPU item)."""
    _, _, plan = small_system
    for op in (plan.proj, plan.back):
        _, b, s, _, _ = op.inds.shape
        assert smem_bytes(b, s, op.winmap.shape[-1]) < 256 << 10, (
            op.winmap.shape
        )


def test_vmem_budget_within_paper_shared_memory():
    """The double-buffered production tile (R=64, K=64, BUF=768, F=16,
    2-byte storage) must fit the ~96 KB-class shared-memory budget the
    paper's multi-stage buffering targets (and far below real VMEM)."""
    assert vmem_bytes(64, 64, 768, 16) < 96 << 10
    # single-slot legacy footprint is smaller still
    assert vmem_bytes(64, 64, 768, 16, stages_buffered=1) < vmem_bytes(
        64, 64, 768, 16
    )
