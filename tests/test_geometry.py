"""Siddon projector: exactness properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.geometry import XCTGeometry, build_system_matrix


def test_axis_aligned_chords():
    """At theta=0 every in-grid ray crosses exactly n voxels of length vox."""
    geo = XCTGeometry(n=16, n_angles=4)
    a = build_system_matrix(geo)
    y = a @ np.ones(geo.n_vox, np.float32)
    assert np.allclose(y[: geo.num_det], 16.0, atol=1e-3)


def test_rotation_invariance_of_mass():
    """Total projected mass is identical for every angle (parallel beam)."""
    geo = XCTGeometry(n=24, n_angles=12)
    a = build_system_matrix(geo)
    rng = np.random.default_rng(0)
    # support inside the inscribed circle so no mass leaves the detector
    img = rng.random((24, 24)).astype(np.float32)
    yy, xx = np.mgrid[0:24, 0:24]
    r = ((xx - 11.5) ** 2 + (yy - 11.5) ** 2) ** 0.5
    img[r > 10] = 0.0
    y = (a @ img.ravel()).reshape(12, geo.num_det)
    mass = y.sum(axis=1)
    # invariant up to ray-sampling discretization (~2% at n=24: one ray
    # per voxel-width samples a sharp-edged random image)
    assert np.allclose(mass, mass.mean(), rtol=4e-2)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=8, max_value=40),
    st.integers(min_value=4, max_value=24),
)
def test_adjoint_property(n, k):
    """<A x, y> == <x, A^T y> -- the invariant CGNR depends on."""
    geo = XCTGeometry(n=n, n_angles=k)
    a = build_system_matrix(geo)
    rng = np.random.default_rng(n * 100 + k)
    x = rng.normal(size=geo.n_vox)
    y = rng.normal(size=geo.n_rays)
    assert np.isclose(
        y @ (a @ x), (a.T @ y) @ x, rtol=1e-6
    )


def test_ray_lengths_bounded():
    geo = XCTGeometry(n=32, n_angles=16)
    a = build_system_matrix(geo)
    assert a.data.min() > 0
    assert a.data.max() <= np.sqrt(2.0) * geo.vox + 1e-6
    # every ray crosses at most 2n voxels
    rows = np.diff(a.indptr)
    assert rows.max() <= 2 * geo.n
