import os
import sys

# Tests must see the default (single) CPU device -- only the dry-run forces
# 512 placeholder devices.  Keep compile parallelism low: 1 core.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def small_system():
    """Shared small geometry + system matrix + plan (memoized)."""
    from repro.core.geometry import XCTGeometry, build_system_matrix
    from repro.core.partition import PartitionConfig, build_plan

    # Crowther criterion: K >= ~pi/2 * n angles for a well-posed inverse
    geo = XCTGeometry(n=32, n_angles=48)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=1, tile=4, rows_per_block=16, nnz_per_stage=16
    )
    plan = build_plan(geo, cfg, a=a)
    return geo, a, plan


@pytest.fixture(scope="session")
def phantom32(small_system):
    from repro.data.phantom import phantom_slices

    geo, a, _ = small_system
    x = phantom_slices(geo.n, 4)
    y = (a @ x).astype(np.float32)
    return x, y
