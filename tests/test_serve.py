"""repro.serve acceptance: plan cache, admission, batching, previews.

Pins the ISSUE 6 criteria:

  * warm path: a second same-geometry job performs ZERO partition or
    winseg builds (cache counters) and its queue-to-first-slab is
    strictly below the cold job's;
  * three concurrent jobs batched through one server each reconstruct
    bit-exact vs running the same job alone through
    ``stream.reconstruct_streaming``;
  * admission rejects work that can never fit and bounds the backlog;
  * a failing job is contained: its batch mates still complete.
"""
import os
import threading

import numpy as np
import pytest

from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices, simulate_measurements
from repro.serve import (
    AdmissionController,
    Job,
    JobCost,
    JobSpec,
    PlanCache,
    ReconServer,
    fair_order,
    form_batch,
)
from repro.stream import SlabStore, reconstruct_streaming

Y = 8  # slices per job (multiple of fuse=2)
ITERS = 4
Y_SLAB = 4
BUDGET = 2 * 2**30


@pytest.fixture(scope="module")
def geo(small_system):
    return small_system[0]


@pytest.fixture(scope="module")
def pcfg():
    return PartitionConfig(
        n_data=1, tile=4, rows_per_block=16, nnz_per_stage=16
    )


@pytest.fixture(scope="module")
def rcfg():
    return ReconConfig(precision="single", comm_mode="rs", fuse=2)


@pytest.fixture(scope="module")
def sinos(small_system):
    geo, a, _ = small_system
    out = []
    for seed in (11, 12, 13):
        x = phantom_slices(geo.n, Y, seed=seed)
        out.append(simulate_measurements(a, x, noise=0.01, seed=seed))
    return out


@pytest.fixture(scope="module")
def reference(geo, pcfg, rcfg, sinos, tmp_path_factory):
    """Each job's volume, run ALONE through the streaming driver."""
    plan = build_plan(geo, pcfg)
    rec = Reconstructor(plan, cfg=rcfg)
    vols = []
    for i, sino in enumerate(sinos):
        tmp = tmp_path_factory.mktemp(f"ref{i}")
        store = SlabStore.from_array(
            str(tmp / "sino"), sino, slab=Y_SLAB
        )
        res = reconstruct_streaming(
            rec, store, str(tmp / "vol"), iters=ITERS, y_slab=Y_SLAB
        )
        vols.append(res.volume.to_array())
    return vols


def _spec(geo, sino, pcfg, rcfg, **kw):
    kw.setdefault("iters", ITERS)
    kw.setdefault("y_slab", Y_SLAB)
    return JobSpec(geo=geo, sino=sino, pcfg=pcfg, rcfg=rcfg, **kw)


# --------------------------------------------------------------------- #
# the warm path (tentpole acceptance)
# --------------------------------------------------------------------- #
def test_warm_job_skips_cold_path_and_is_faster(
    geo, pcfg, rcfg, sinos, tmp_path
):
    srv = ReconServer(BUDGET, workdir=str(tmp_path))
    cold = srv.submit(_spec(geo, sinos[0], pcfg, rcfg))
    assert srv.drain() == 1 and cold.status == "done"
    assert srv.cache.stats()["builds"] == 1
    assert cold.telemetry.plan_cold

    warm = srv.submit(_spec(geo, sinos[1], pcfg, rcfg, tenant="b"))
    assert warm.plan_key == cold.plan_key
    assert srv.drain() == 1 and warm.status == "done"
    st = srv.cache.stats()
    # ZERO new partition/winseg builds: the cache counters are the proof
    assert st["builds"] == 1 and st["misses"] == 1 and st["hits"] == 1
    assert not warm.telemetry.plan_cold
    # and the warm job reaches its first slab strictly sooner
    assert (
        warm.telemetry.first_slab_s
        < cold.telemetry.first_slab_s
    )


def test_concurrent_jobs_bit_exact_vs_streaming(
    geo, pcfg, rcfg, sinos, reference, tmp_path
):
    events = []
    srv = ReconServer(
        BUDGET, workdir=str(tmp_path),
        on_preview=lambda job, pv: events.append(
            (job.id, job.status, pv.j0, pv.j1)
        ),
    )
    jobs = [
        srv.submit(_spec(geo, s, pcfg, rcfg, tenant=f"t{i}"))
        for i, s in enumerate(sinos)
    ]
    assert srv.drain() == 3
    # one batch, one cold build, everything coalesced
    assert len(srv.batches) == 1
    assert srv.batches[0]["jobs"] == [j.id for j in jobs]
    assert srv.cache.stats()["builds"] == 1
    for job, ref in zip(jobs, reference):
        assert job.status == "done"
        np.testing.assert_array_equal(job.volume.to_array(), ref)
        assert job.resnorms.shape == (ITERS, Y)
    # previews streamed round-robin while every job was still running
    assert all(status == "running" for _, status, _, _ in events)
    first_three = [jid for jid, _, _, _ in events[:3]]
    assert sorted(first_three) == [j.id for j in jobs]
    # telemetry split covers the work
    for job in jobs:
        t = job.telemetry
        assert t.n_slabs == Y // Y_SLAB
        assert t.solve_s > 0 and t.total_s > 0


def test_jobs_visible_and_volumes_on_disk(geo, pcfg, rcfg, sinos,
                                          tmp_path):
    srv = ReconServer(BUDGET, workdir=str(tmp_path))
    job = srv.submit(_spec(geo, sinos[0], pcfg, rcfg))
    srv.drain()
    assert srv.job(job.id) is job
    assert job.volume.complete()
    for pv in job.previews:
        assert os.path.exists(pv.path)  # previews ARE the shards
    st = srv.stats()
    assert st["completed"] == 1 and st["queued"] == 0


# --------------------------------------------------------------------- #
# admission control
# --------------------------------------------------------------------- #
def test_admission_rejects_impossible_jobs(geo, pcfg, rcfg, sinos,
                                           tmp_path):
    srv = ReconServer(2**20, workdir=str(tmp_path))  # 1 MiB: hopeless
    job = srv.submit(_spec(geo, sinos[0], pcfg, rcfg, y_slab=None))
    assert job.status == "rejected" and job.terminal
    assert "mem_budget" in job.error
    assert srv.stats()["rejected"] == 1
    assert srv.cache.stats()["builds"] == 0  # pricing never builds


def test_admission_rejects_bad_specs(geo, pcfg, rcfg, sinos, tmp_path):
    srv = ReconServer(BUDGET, workdir=str(tmp_path))
    wrong_rows = np.zeros((7, Y), np.float32)
    j = srv.submit(_spec(geo, wrong_rows, pcfg, rcfg))
    assert j.status == "rejected" and "rays" in j.error
    odd = srv.submit(
        _spec(geo, sinos[0][:, :5], pcfg, rcfg, y_slab=None)
    )
    assert odd.status == "rejected" and "granule" in odd.error
    ragged = srv.submit(_spec(geo, sinos[0], pcfg, rcfg, y_slab=3))
    assert ragged.status == "rejected" and "multiple" in ragged.error
    assert srv.drain() == 0


def test_admission_bounds_the_backlog(geo, pcfg, rcfg, sinos, tmp_path):
    srv = ReconServer(BUDGET, workdir=str(tmp_path), max_queue=2)
    a = srv.submit(_spec(geo, sinos[0], pcfg, rcfg))
    b = srv.submit(_spec(geo, sinos[1], pcfg, rcfg))
    c = srv.submit(_spec(geo, sinos[2], pcfg, rcfg))
    assert a.status == "queued" and b.status == "queued"
    assert c.status == "rejected" and "queue full" in c.error
    # the queued work still runs
    assert srv.drain() == 2


def test_admission_fits_shares_the_operator():
    cost = JobCost(
        fixed_bytes=100, per_slice_bytes=2, y_slab=10, n_slices=40
    )
    adm = AdmissionController.__new__(AdmissionController)
    adm.mem_budget = 150
    assert cost.working_bytes == 20 and cost.slab_bytes == 120
    assert cost.n_slabs == 4
    assert adm.fits([cost, cost])  # 100 + 2*20 = 140 <= 150
    assert not adm.fits([cost, cost, cost])  # 160 > 150
    assert adm.fits([])


# --------------------------------------------------------------------- #
# batching policy (pure units)
# --------------------------------------------------------------------- #
def _fake_job(jid, key="k", tenant="a", priority=0):
    spec = JobSpec(
        geo=None, sino=np.zeros((1, 2), np.float32),
        tenant=tenant, priority=priority,
    )
    return Job(jid, spec, key)


def test_fair_order_priority_then_least_served_then_fifo():
    jobs = [
        _fake_job(0, tenant="greedy"),
        _fake_job(1, tenant="greedy"),
        _fake_job(2, tenant="new"),
        _fake_job(3, tenant="vip", priority=5),
    ]
    served = {"greedy": 100.0, "new": 0.0}
    order = [j.id for j in fair_order(jobs, served)]
    # priority first; then the under-served tenant; FIFO within a tenant
    assert order == [3, 2, 0, 1]


def test_form_batch_coalesces_same_key_under_budget():
    jobs = [
        _fake_job(0, key="k1"),
        _fake_job(1, key="k2"),
        _fake_job(2, key="k1"),
        _fake_job(3, key="k1"),
    ]
    costs = {
        j.id: JobCost(
            fixed_bytes=100, per_slice_bytes=1, y_slab=20, n_slices=20
        )
        for j in jobs
    }
    adm = AdmissionController.__new__(AdmissionController)
    adm.mem_budget = 150  # 100 fixed + two 20-byte working sets
    batch = form_batch(jobs, costs, adm, max_batch=4)
    # k2 never joins a k1 batch; the third k1 job does not fit
    assert [j.id for j in batch] == [0, 2]
    batch2 = form_batch(jobs, costs, adm, max_batch=1)
    assert [j.id for j in batch2] == [0]


def test_priority_orders_real_batches(geo, pcfg, rcfg, sinos, tmp_path):
    srv = ReconServer(BUDGET, workdir=str(tmp_path), max_batch=2)
    lo = [
        srv.submit(_spec(geo, sinos[i], pcfg, rcfg)) for i in range(2)
    ]
    hi = srv.submit(
        _spec(geo, sinos[2], pcfg, rcfg, tenant="vip", priority=9)
    )
    assert srv.drain() == 3
    # the priority job leads the first batch despite submitting last
    assert srv.batches[0]["jobs"][0] == hi.id
    assert {j.id for j in lo} == set(
        srv.batches[0]["jobs"][1:] + srv.batches[1]["jobs"]
    )


# --------------------------------------------------------------------- #
# plan cache (pure units)
# --------------------------------------------------------------------- #
def test_plan_cache_lru_evicts_by_bytes():
    cache = PlanCache(capacity_bytes=100)
    e1, hit = cache.get_or_build("a", lambda: (1, 1, 60))
    assert not hit and cache.bytes == 60
    cache.get_or_build("b", lambda: (2, 2, 60))  # evicts a (LRU)
    assert "a" not in cache and "b" in cache
    assert cache.stats()["evictions"] == 1
    # rebuilding a counts a fresh miss + build
    cache.get_or_build("a", lambda: (1, 1, 60))
    assert cache.stats()["builds"] == 3 and cache.hits == 0
    _, hit = cache.get_or_build("a", lambda: (1, 1, 60))
    assert hit and cache.hits == 1 and cache.hit_rate == 0.25


def test_plan_cache_pin_blocks_eviction():
    cache = PlanCache(capacity_bytes=100)
    cache.get_or_build("a", lambda: (1, 1, 60))
    cache.pin("a")
    cache.get_or_build("b", lambda: (2, 2, 60))  # over budget, a pinned
    assert "a" in cache and "b" in cache  # deferred, not dropped
    cache.unpin("a")  # deferred eviction lands now ("a" is LRU)
    assert "a" not in cache and "b" in cache
    assert cache.peek("zzz") is None
    # peek counts nothing
    before = cache.stats()
    cache.peek("b")
    assert cache.stats() == before


def test_plan_cache_single_entry_never_evicts_its_own_key():
    cache = PlanCache(capacity_bytes=10)  # smaller than any entry
    entry, _ = cache.get_or_build("a", lambda: (1, 1, 60))
    assert "a" in cache  # degrade to rebuild-every-time, not refusal
    cache.get_or_build("b", lambda: (2, 2, 60))
    assert "b" in cache and "a" not in cache


# --------------------------------------------------------------------- #
# failure containment + background mode
# --------------------------------------------------------------------- #
def test_failed_job_does_not_sink_its_batch(geo, pcfg, rcfg, sinos,
                                            tmp_path):
    # a sinogram store missing its second shard: the first slab solves,
    # the second fetch raises -> that job fails, its batch mate finishes
    holey = SlabStore.create(
        str(tmp_path / "holey"), geo.n_rays, Y, Y_SLAB
    )
    holey.write(0, sinos[0][:, :Y_SLAB])
    srv = ReconServer(BUDGET, workdir=str(tmp_path / "srv"))
    bad = srv.submit(_spec(geo, holey, pcfg, rcfg))
    good = srv.submit(_spec(geo, sinos[1], pcfg, rcfg, tenant="b"))
    assert srv.drain() == 2
    assert bad.status == "failed" and "slab load failed" in bad.error
    assert len(bad.previews) == 1  # the slab that did land is published
    assert good.status == "done" and good.volume.complete()
    assert srv.stats()["failed"] == 1 and srv.stats()["completed"] == 1


def test_background_server_drains_submits(geo, pcfg, rcfg, sinos,
                                          tmp_path):
    srv = ReconServer(BUDGET, workdir=str(tmp_path))
    srv.start()
    with pytest.raises(RuntimeError, match="already started"):
        srv.start()
    try:
        jobs = [
            srv.submit(_spec(geo, s, pcfg, rcfg)) for s in sinos[:2]
        ]
        for j in jobs:
            assert j.wait(timeout=300)
            assert j.status == "done"
    finally:
        srv.stop()
    assert srv.stats()["completed"] == 2
    srv.stop()  # idempotent
    assert threading.active_count() >= 1  # no leaked scheduler thread
