"""Unit tests for the HLO collective parser + roofline arithmetic +
sharding-hint selection rules (pure functions, no device work)."""
import numpy as np

from repro.launch.hlo_analysis import (
    HW, analytic_min_hbm, analyze_collectives, roofline,
)


def test_collective_parser_kinds_and_bytes():
    hlo = """
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[2,8]<=[16]
  %rs = f32[8,8]{1,0} reduce-scatter(%z), replica_groups={{0,1},{2,3}}
  %cp = f32[4]{0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
"""
    out = analyze_collectives(hlo, pod_size=0)
    assert out["ops"] == 4
    kinds = out["by_kind"]
    assert kinds["all-reduce"]["bytes"] == 16 * 1024 * 4
    # all-gather result / group size = operand
    assert kinds["all-gather"]["bytes"] == 64 * 128 * 2 // 8
    # reduce-scatter result * group size = operand
    assert kinds["reduce-scatter"]["bytes"] == 8 * 8 * 4 * 2
    assert kinds["collective-permute"]["bytes"] == 16


def test_collective_pod_classification():
    hlo = (
        "  %a = f32[8]{0} all-reduce(%x), "
        "replica_groups={{0,256}}, to_apply=%add\n"
        "  %b = f32[8]{0} all-reduce(%y), "
        "replica_groups={{0,1}}, to_apply=%add\n"
    )
    out = analyze_collectives(hlo, pod_size=256)
    assert out["dci_bytes"] == 32
    assert out["ici_bytes"] == 32


def test_roofline_terms_and_fraction():
    r = roofline(
        flops_dev=HW.peak_flops,  # exactly 1 s of compute
        hbm_bytes_dev=HW.hbm_bw / 2,  # 0.5 s
        ici_bytes_dev=0.0,
        dci_bytes_dev=0.0,
        useful_flops_dev=HW.peak_flops / 2,
        hbm_bytes_analytic=HW.hbm_bw / 4,
    )
    assert r["dominant"] == "compute"
    assert abs(r["t_step"] - 1.0) < 1e-9
    assert abs(r["roofline_fraction"] - 0.5) < 1e-9
    assert r["dominant_adj"] == "compute"
    assert abs(r["model_flops_ratio"] - 0.5) < 1e-9


def test_analytic_hbm_monotone_in_batch():
    import types

    from repro.configs import get_config

    cfg = get_config("qwen3-4b", max_cache=1024)
    mesh = types.SimpleNamespace(
        shape={"data": 16, "model": 16}, size=256
    )
    small = analytic_min_hbm(cfg, "train", 16, 1024, mesh)
    big = analytic_min_hbm(cfg, "train", 64, 1024, mesh)
    assert big > small > 0


def test_hint_rules():
    import os

    os.environ.setdefault(
        "XLA_FLAGS", ""
    )  # _hint_overrides only touches configs
    from repro.launch.dryrun import _hint_overrides

    # kv divides -> no q-shard, no merge
    ov = _hint_overrides("codeqwen1.5-7b", ("data",), "train")
    assert not ov["attn_q_shard"] and not ov["attn_heads_merge"]
    # prefill with indivisible kv -> q-shard
    ov = _hint_overrides("deepseek-coder-33b", ("data",), "prefill")
    assert ov["attn_q_shard"]
    # train with divisible total heads -> merge
    ov = _hint_overrides("qwen3-4b", ("data",), "train")
    assert ov["attn_heads_merge"] and not ov["attn_q_shard"]
    # MQA -> q-shard even in train
    ov = _hint_overrides("recurrentgemma-9b", ("data",), "train")
    assert ov["attn_q_shard"]
