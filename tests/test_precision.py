"""Mixed precision: adaptive normalization properties (paper III-C)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.precision import (
    ALIASES,
    POLICIES,
    adaptive_scale,
    dequantize_block_vals,
    get_policy,
    qcast,
    quantize_block_vals,
)


def test_policies_registry():
    for name in ("double", "single", "half", "mixed", "mixed_bf16"):
        p = get_policy(name)
        assert p.name == name
    assert POLICIES["mixed"].adaptive
    assert POLICIES["mixed"].storage_bytes == 2
    assert POLICIES["single"].comm_bytes == 4


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-30, max_value=1e30))
def test_adaptive_scale_is_power_of_two(mag):
    x = jnp.asarray([mag, -mag / 3], jnp.float32)
    s = float(adaptive_scale(x))
    assert s > 0
    m = np.log2(s)
    assert abs(m - round(m)) < 1e-6  # lossless power-of-two factor


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-25, max_value=1e25))
def test_adaptive_scale_steers_to_target(mag):
    x = jnp.asarray([mag], jnp.float32)
    s = float(adaptive_scale(x, target=256.0))
    assert 128.0 <= mag * s <= 512.0  # within one octave of target


def test_qcast_roundtrip_protects_small_values():
    """Values that underflow a plain fp16 cast survive adaptive qcast."""
    x = jnp.asarray([3e-6, 5e-6, -4e-6], jnp.float32)
    plain = x.astype(jnp.float16).astype(jnp.float32)
    assert float(jnp.abs(plain).max()) < 6e-6  # heavy quantization
    q, inv = qcast(x, jnp.float16, adaptive=True)
    back = q.astype(jnp.float32) * inv
    np.testing.assert_allclose(
        np.asarray(back), np.asarray(x), rtol=1e-3
    )


def test_qcast_wide_dtype_is_identity():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    q, inv = qcast(x, jnp.float32, adaptive=True)
    assert float(inv) == 1.0
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


# --------------------------------------------------------------------- #
# quantized ladder rung (ISSUE 8): registry, aliases, per-block scaling
# --------------------------------------------------------------------- #
def test_quantized_policy_decouples_vals_from_storage():
    q8 = get_policy("q8")
    assert q8.quantized
    assert q8.vals_dtype == jnp.int8
    assert q8.vals_bytes == 1
    # vectors / wire stay at the mixed tier's widths
    assert q8.storage_bytes == 2
    assert q8.comm_bytes == 2
    assert q8.adaptive
    # non-quantized policies: vals defaults to the storage dtype
    mixed = get_policy("mixed")
    assert not mixed.quantized
    assert mixed.vals_bytes == mixed.storage_bytes == 2
    assert get_policy("single").vals_dtype == jnp.float32
    # fp8 rung is gated on the jax build shipping the dtype
    if hasattr(jnp, "float8_e4m3fn"):
        fp8 = get_policy("fp8")
        assert fp8.quantized and fp8.vals_bytes == 1


def test_get_policy_aliases():
    assert get_policy("f32") is get_policy("single")
    assert get_policy("f64") is get_policy("double")
    assert get_policy("f16") is get_policy("half")
    assert get_policy("int8") is get_policy("q8")


def test_get_policy_error_enumerates_names_and_aliases():
    with pytest.raises(KeyError) as ei:
        get_policy("fp32")
    msg = str(ei.value)
    for name in sorted(POLICIES):
        assert name in msg
    for alias, target in ALIASES.items():
        assert f"{alias}->{target}" in msg


@settings(max_examples=40, deadline=None)
@given(
    st.floats(min_value=1e-20, max_value=1e20),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quantize_block_roundtrip_bounds_error(mag, seed):
    """Per-block power-of-two scaling: the round-trip error of every
    block is bounded by half an int8 quantization step of the block's
    own max (<= 1/254 relative), the scale exponents are exact ints
    (lossless to apply), and no value clips."""
    rng = np.random.default_rng(seed)
    vals = (mag * rng.standard_normal((3, 2, 4, 16))).astype(np.float32)
    q, exp = quantize_block_vals(jnp.asarray(vals), jnp.int8)
    assert q.dtype == jnp.int8 and exp.dtype == jnp.int32
    # one scale per (leading dims) block of [R, K] values
    assert q.shape == vals.shape and exp.shape == vals.shape[:-2]
    qn = np.asarray(q, np.float64)
    assert np.abs(qn).max() <= 127  # floor-rounded scale never clips
    back = np.asarray(dequantize_block_vals(q, exp), np.float64)
    for b in range(vals.shape[0]):
        for s in range(vals.shape[1]):
            m = np.abs(vals[b, s]).max()
            if m == 0.0:
                np.testing.assert_array_equal(back[b, s], 0.0)
                continue
            # scaled block max lands in (target/2, target]: the grid is
            # used efficiently, so the step is at most m/63.5
            assert 63.5 < np.abs(qn[b, s]).max() <= 127.0
            err = np.abs(back[b, s] - vals[b, s]).max()
            assert err <= 0.5 * m / 63.5


def test_quantize_block_scales_are_powers_of_two():
    vals = jnp.asarray(
        np.random.default_rng(7).standard_normal((2, 3, 8)), jnp.float32
    )
    q, exp = quantize_block_vals(vals, jnp.int8)
    # dequant multiplies by 2**exp -- an int exponent IS the proof, but
    # also check the factor reconstructs bit-exactly through ldexp
    scale = np.ldexp(1.0, np.asarray(exp)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(dequantize_block_vals(q, exp)),
        np.asarray(q, np.float32) * scale[..., None, None],
    )


def _iters_to_tol(res, tol):
    """First CGNR iteration whose residual drops below tol * res0."""
    hit = np.nonzero(res[:, 0] < tol * res[0, 0])[0]
    return int(hit[0]) if hit.size else len(res)


def _psnr(x, x_true):
    mse = float(np.mean((x - x_true) ** 2))
    return 10.0 * np.log10(float(x_true.max()) ** 2 / mse)


def test_convergence_ladder(small_system, phantom32):
    """Acceptance (ISSUE 8): down the ladder single -> half -> bf16 ->
    q8, CGNR run to a fixed residual tolerance takes <= 1.1x the f32
    iteration count, and the image AT that stopping point lands within
    0.5 dB PSNR of f32's (paper Fig. 13: no serious convergence
    degradation).  The bf16 rung is the paper's scheme -- bf16 storage
    *with* the Sec. III-C adaptive normalization (``mixed_bf16``); the
    non-adaptive all-bf16 compute tier needs ~1.2x the iterations (8
    mantissa bits) and is not part of the production ladder."""
    from repro.core.recon import ReconConfig, Reconstructor

    _, _, plan = small_system
    x_true, y = phantom32
    budget, tol = 25, 0.05
    out = {}
    for prec in ("single", "half", "mixed_bf16", "q8"):
        rec = Reconstructor(
            plan, cfg=ReconConfig(precision=prec, comm_mode="rs", fuse=2)
        )
        _, res = rec.reconstruct(y, iters=budget)
        it = _iters_to_tol(np.asarray(res), tol)
        x, _ = rec.reconstruct(y, iters=it)  # the image at the stop
        out[prec] = (it, _psnr(np.asarray(x), x_true))
    it32, psnr32 = out["single"]
    assert it32 < budget  # the budget actually exercises the bound
    for prec, (it, psnr) in out.items():
        assert it <= np.ceil(1.1 * it32), (prec, it, it32)
        assert psnr >= psnr32 - 0.5, (prec, psnr, psnr32)
