"""Mixed precision: adaptive normalization properties (paper III-C)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.precision import POLICIES, adaptive_scale, get_policy, qcast


def test_policies_registry():
    for name in ("double", "single", "half", "mixed", "mixed_bf16"):
        p = get_policy(name)
        assert p.name == name
    assert POLICIES["mixed"].adaptive
    assert POLICIES["mixed"].storage_bytes == 2
    assert POLICIES["single"].comm_bytes == 4


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-30, max_value=1e30))
def test_adaptive_scale_is_power_of_two(mag):
    x = jnp.asarray([mag, -mag / 3], jnp.float32)
    s = float(adaptive_scale(x))
    assert s > 0
    m = np.log2(s)
    assert abs(m - round(m)) < 1e-6  # lossless power-of-two factor


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-25, max_value=1e25))
def test_adaptive_scale_steers_to_target(mag):
    x = jnp.asarray([mag], jnp.float32)
    s = float(adaptive_scale(x, target=256.0))
    assert 128.0 <= mag * s <= 512.0  # within one octave of target


def test_qcast_roundtrip_protects_small_values():
    """Values that underflow a plain fp16 cast survive adaptive qcast."""
    x = jnp.asarray([3e-6, 5e-6, -4e-6], jnp.float32)
    plain = x.astype(jnp.float16).astype(jnp.float32)
    assert float(jnp.abs(plain).max()) < 6e-6  # heavy quantization
    q, inv = qcast(x, jnp.float16, adaptive=True)
    back = q.astype(jnp.float32) * inv
    np.testing.assert_allclose(
        np.asarray(back), np.asarray(x), rtol=1e-3
    )


def test_qcast_wide_dtype_is_identity():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    q, inv = qcast(x, jnp.float32, adaptive=True)
    assert float(inv) == 1.0
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))
