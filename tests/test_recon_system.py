"""End-to-end system behaviour: distributed machinery == plain SpMM + CG."""
import numpy as np
import pytest

from repro.core.recon import ReconConfig, Reconstructor


def test_project_backproject_match_scipy(small_system, phantom32):
    geo, a, plan = small_system
    x, y = phantom32
    rec = Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )
    yhat = rec.project(x)
    np.testing.assert_allclose(yhat, a @ x, rtol=2e-4, atol=2e-4)
    bt = rec.backproject(y)
    ref = a.T @ y
    np.testing.assert_allclose(
        bt, ref, rtol=2e-4, atol=2e-4 * np.abs(ref).max()
    )


def test_reconstruction_converges(small_system, phantom32):
    _, _, plan = small_system
    x_true, y = phantom32
    rec = Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )
    x, res = rec.reconstruct(y, iters=25)
    rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
        x_true, axis=0
    )
    # sharp-edged phantom: CGNR reaches ~15% at 25 iters (lsqr floor is
    # ~1.2% at 200); the paper also stops at 24-30 iters
    assert rel.mean() < 0.2, rel
    assert res[-1, 0] < 0.05 * res[0, 0]


@pytest.mark.parametrize("precision", ["mixed", "half", "mixed_bf16"])
def test_reduced_precision_tracks_single(
    small_system, phantom32, precision
):
    """Paper Fig. 13: reduced precision shows no serious convergence
    degradation (numerical noise floor below measurement scale)."""
    _, _, plan = small_system
    x_true, y = phantom32
    xs = {}
    for prec in ("single", precision):
        rec = Reconstructor(
            plan, cfg=ReconConfig(precision=prec, comm_mode="rs", fuse=2)
        )
        x, _ = rec.reconstruct(y, iters=15)
        xs[prec] = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
            x_true, axis=0
        )
    assert xs[precision].mean() < xs["single"].mean() + 0.03


def test_overlap_pipeline_matches_sync(small_system, phantom32):
    """Fig. 8 software pipelining must be a pure schedule change."""
    _, _, plan = small_system
    _, y = phantom32
    outs = []
    for overlap in (False, True):
        rec = Reconstructor(
            plan,
            cfg=ReconConfig(
                precision="single", comm_mode="rs", fuse=2,
                overlap=overlap,
            ),
        )
        x, _ = rec.reconstruct(y, iters=5)
        outs.append(x)
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)


def test_oracle_path_matches_kernel_path(small_system, phantom32):
    _, _, plan = small_system
    _, y = phantom32
    outs = []
    for use_ref in (False, True):
        rec = Reconstructor(
            plan,
            cfg=ReconConfig(
                precision="mixed", comm_mode="rs", fuse=2, use_ref=use_ref
            ),
        )
        x, _ = rec.reconstruct(y, iters=5)
        outs.append(x)
    np.testing.assert_allclose(outs[0], outs[1], rtol=5e-3, atol=5e-3)
