"""tools/bench_check.py: the bench-smoke regression gate (ISSUE 5).

``ai`` is gated absolutely (deterministic model output); ``slices_per_s``
is gated after machine normalization (suite-mean rescale), so a uniformly
slower CI runner passes while a row regressing relative to its
suite-mates fails.
"""
import json
import pathlib
import sys

import pytest

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "tools")
)
import bench_check  # noqa: E402


def _write(d: pathlib.Path, name: str, rows: list):
    d.mkdir(parents=True, exist_ok=True)
    (d / name).write_text(json.dumps(rows))


ROWS = [
    {"name": "stream/slab2/sync", "slices_per_s": 10.0, "ai": 0.5},
    {"name": "stream/slab2/overlap_dev", "slices_per_s": 12.0,
     "ai": 0.5},
    {"name": "stream/slab4/sync", "slices_per_s": 8.0, "ai": 0.5},
    {"name": "stream/slab4/overlap_dev", "slices_per_s": 10.0,
     "ai": 0.5},
]


def _run(tmp_path, fresh_rows, name="BENCH_stream.json"):
    _write(tmp_path / "base", "BENCH_stream.json", ROWS)
    _write(tmp_path / "fresh", name, fresh_rows)
    return bench_check.main(
        ["--baseline", str(tmp_path / "base"),
         "--fresh", str(tmp_path / "fresh")]
    )


def test_identical_passes(tmp_path):
    assert _run(tmp_path, ROWS) == 0


def test_uniform_runner_slowdown_passes(tmp_path):
    """A 2x slower machine must not fail the wall-clock gate: the
    comparison is machine-normalized."""
    slow = [dict(r, slices_per_s=r["slices_per_s"] * 0.5) for r in ROWS]
    assert _run(tmp_path, slow) == 0


def test_relative_throughput_regression_fails(tmp_path):
    """One row collapsing relative to its suite-mates fails even after
    machine normalization."""
    bad = [dict(r) for r in ROWS]
    bad[0]["slices_per_s"] = 3.0  # 70% down; suite mean barely moves
    assert _run(tmp_path, bad) == 1


def test_modeled_ai_regression_fails_absolutely(tmp_path):
    bad = [dict(r) for r in ROWS]
    bad[1]["ai"] = 0.3  # 40% down, deterministic field
    assert _run(tmp_path, bad) == 1


def test_small_wobble_and_new_rows_pass(tmp_path):
    """<=25% noise and added/dropped rows do not fail the gate."""
    ok = [dict(r) for r in ROWS[:3]]  # one row dropped
    ok[0]["slices_per_s"] *= 0.85  # within threshold after rescale
    ok.append({"name": "stream/slab8/new", "slices_per_s": 1.0})
    assert _run(tmp_path, ok) == 0


def test_improvements_pass(tmp_path):
    up = [dict(r, slices_per_s=r["slices_per_s"] * 3, ai=1.0)
          for r in ROWS]
    assert _run(tmp_path, up) == 0


def test_unknown_suite_skipped_but_zero_compared_fails(tmp_path):
    """A fresh suite without a baseline is skipped -- but comparing
    NOTHING is a failure (a mispointed gate must not pass silently)."""
    _write(tmp_path / "base", "BENCH_stream.json", ROWS)
    _write(tmp_path / "fresh", "BENCH_stream.json", ROWS)
    _write(tmp_path / "fresh", "BENCH_new_suite.json", ROWS)
    rc = bench_check.main(
        ["--baseline", str(tmp_path / "base"),
         "--fresh", str(tmp_path / "fresh")]
    )
    assert rc == 0  # stream compared, new suite skipped
    empty = tmp_path / "nothing"
    empty.mkdir()
    rc = bench_check.main(
        ["--baseline", str(empty), "--fresh", str(tmp_path / "fresh")]
    )
    assert rc == 1  # zero suites compared == broken gate


@pytest.mark.parametrize("scale", [0.5, 2.0])
def test_normalization_reports_scale(tmp_path, capsys, scale):
    bad = [dict(r, slices_per_s=r["slices_per_s"] * scale) for r in ROWS]
    bad[0]["slices_per_s"] = ROWS[0]["slices_per_s"] * scale * 0.25
    assert _run(tmp_path, bad) == 1
    assert "machine-normalized" in capsys.readouterr().out


def test_single_big_improvement_does_not_flag_others(tmp_path):
    """Median normalization: one genuine 4x win in one row must not
    drag the unchanged rows into false regressions (a mean-based scale
    would)."""
    up = [dict(r) for r in ROWS]
    up[1]["slices_per_s"] = ROWS[1]["slices_per_s"] * 4
    assert _run(tmp_path, up) == 0
