"""CGNR solver against dense least-squares ground truth."""
import jax.numpy as jnp
import numpy as np

from repro.core.solver import cgnr


def _dense_ops(a):
    aj = jnp.asarray(a)

    def fwd(x):
        return aj @ x

    def bwd(y):
        return aj.T @ y

    def dot(u, v):
        return jnp.sum(u * v, axis=0)

    return fwd, bwd, dot


def test_cgnr_solves_least_squares():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(60, 24)).astype(np.float32)
    x_true = rng.normal(size=(24, 3)).astype(np.float32)
    y = a @ x_true
    fwd, bwd, dot = _dense_ops(a)
    x, res = cgnr(
        fwd, bwd, jnp.asarray(y), jnp.zeros((24, 3)), 40, dot
    )
    np.testing.assert_allclose(np.asarray(x), x_true, atol=2e-3)
    # residuals are monotonically non-increasing (within float noise)
    r = np.asarray(res)
    assert (np.diff(r[:, 0]) < 1e-3).all()


def test_cgnr_per_slice_independence():
    """Scaling one slice's data must not change another slice's iterate
    (per-slice alpha/beta -- slices are independent problems)."""
    rng = np.random.default_rng(1)
    a = rng.normal(size=(40, 16)).astype(np.float32)
    y = (a @ rng.normal(size=(16, 2))).astype(np.float32)
    fwd, bwd, dot = _dense_ops(a)
    x1, _ = cgnr(fwd, bwd, jnp.asarray(y), jnp.zeros((16, 2)), 10, dot)
    y2 = y.copy()
    y2[:, 1] *= 100.0
    x2, _ = cgnr(fwd, bwd, jnp.asarray(y2), jnp.zeros((16, 2)), 10, dot)
    np.testing.assert_allclose(
        np.asarray(x1)[:, 0], np.asarray(x2)[:, 0], rtol=1e-5
    )


def test_cgnr_half_storage_converges():
    rng = np.random.default_rng(2)
    a = rng.normal(size=(80, 32)).astype(np.float32)
    x_true = rng.normal(size=(32, 2)).astype(np.float32)
    y = a @ x_true
    fwd, bwd, dot = _dense_ops(a)
    x, _ = cgnr(
        fwd, bwd, jnp.asarray(y), jnp.zeros((32, 2)), 30, dot,
        storage_dtype=jnp.float16,
    )
    rel = np.linalg.norm(np.asarray(x) - x_true) / np.linalg.norm(x_true)
    assert rel < 0.05
