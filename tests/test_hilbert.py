"""Properties of the pseudo-Hilbert ordering."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hilbert import (
    gilbert2d, hilbert_curve_square, hilbert_order, tile_hilbert_order,
)

sides = st.integers(min_value=1, max_value=23)


def test_square_curve_is_contiguous():
    """On power-of-two squares the curve is a true Hilbert curve."""
    for order in (1, 2, 3, 4):
        pts = hilbert_curve_square(order)
        n = 1 << order
        assert len(set(map(tuple, pts))) == n * n
        steps = np.abs(np.diff(pts, axis=0)).sum(axis=1)
        assert (steps == 1).all(), order


@settings(max_examples=40, deadline=None)
@given(sides, sides)
def test_pseudo_curve_visits_every_cell_once(w, h):
    pts = gilbert2d(w, h)
    assert pts.shape == (w * h, 2)
    assert len({(int(x), int(y)) for x, y in pts}) == w * h
    assert pts[:, 0].min() == 0 and pts[:, 0].max() == w - 1
    assert pts[:, 1].min() == 0 and pts[:, 1].max() == h - 1


@settings(max_examples=25, deadline=None)
@given(sides, sides)
def test_hilbert_order_is_permutation(w, h):
    order = hilbert_order(w, h)
    assert sorted(order.tolist()) == list(range(w * h))


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=4, max_value=32),
    st.integers(min_value=4, max_value=32),
)
def test_pseudo_curve_locality(w, h):
    """The property the decomposition relies on: each quarter of the curve
    occupies a compact bounding box (not a thin slab)."""
    pts = gilbert2d(w, h)
    quarter = max(1, len(pts) // 4)
    for q in range(4):
        chunk = pts[q * quarter : (q + 1) * quarter]
        if len(chunk) < 4:
            continue
        area = (
            (chunk[:, 0].max() - chunk[:, 0].min() + 1)
            * (chunk[:, 1].max() - chunk[:, 1].min() + 1)
        )
        assert area <= 4.0 * len(chunk) + 8, (q, area, len(chunk))


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=2, max_value=40),
    st.integers(min_value=2, max_value=40),
    st.sampled_from([2, 4, 8]),
)
def test_tile_order_is_permutation(rows, cols, tile):
    perm, _ = tile_hilbert_order(rows, cols, tile)
    assert sorted(perm.tolist()) == list(range(rows * cols))


def test_tile_order_locality():
    """Contiguous curve chunks form spatially-compact subdomains: the
    bounding box of each quarter of the curve is far smaller than the
    full grid (this is what makes hierarchical reduction pay off)."""
    n, tile = 32, 4
    perm, _ = tile_hilbert_order(n, n, tile)
    quarter = len(perm) // 4
    for q in range(4):
        cells = perm[q * quarter : (q + 1) * quarter]
        r, c = cells // n, cells % n
        area = (r.max() - r.min() + 1) * (c.max() - c.min() + 1)
        assert area <= 2.5 * quarter, (q, area, quarter)
