"""Docs stay true: doc examples execute, intra-repo links resolve.

Runs ``tools/check_docs.py`` in a subprocess because importing
``repro.launch.dryrun`` (one of the doctest'd modules) sets XLA_FLAGS
for 512 placeholder devices, which must not leak into this process's
jax.
"""
import os
import subprocess
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_doc_examples_and_links():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "tools", "check_docs.py")],
        capture_output=True, text=True, env=env, timeout=600, cwd=_ROOT,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
