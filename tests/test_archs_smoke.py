"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness, and prefill/decode == full-forward
consistency (the cache invariant every serving path depends on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.lm import decode_step, loss_fn, prefill
from repro.models.transformer import forward, init_params

B, T = 2, 24


def _inputs(cfg, key, t=T):
    if cfg.embed_inputs:
        return jax.random.randint(key, (B, t), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, t, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_shapes_and_finite(name):
    cfg = get_config(name, smoke=True, max_cache=T + 8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {
        "inputs": _inputs(cfg, key),
        "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
    }
    loss, metrics = jax.jit(
        lambda p: loss_fn(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss)), name
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    logits, _, _ = forward(
        params, cfg, batch["inputs"], positions=positions, mode="train"
    )
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name):
    """logits(prefill T tokens, then decode token T) must equal the full
    forward pass over T+1 tokens at position T.

    MoE archs get ample capacity: expert-capacity drops legitimately
    differ between a T-token dispatch group and a 1-token one."""
    cfg = get_config(
        name, smoke=True, max_cache=T + 8, moe_capacity_factor=8.0
    )
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    full_in = _inputs(cfg, key, T + 1)
    positions = jnp.broadcast_to(jnp.arange(T + 1), (B, T + 1))
    ref_logits, _, _ = forward(
        params, cfg, full_in, positions=positions, mode="train"
    )

    _, cache = prefill(params, cfg, full_in[:, :T])
    last = full_in[:, T:]
    _, _, dec_logits = decode_step(
        params, cfg, cache, last, jnp.int32(T)
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits),
        np.asarray(ref_logits[:, T]),
        rtol=2e-2, atol=2e-2,
    )


def test_sliding_window_cache_wraps():
    """recurrentgemma local attention: decode beyond the window must agree
    with a full forward that sees only the window (ring buffer unwrap)."""
    cfg = get_config("recurrentgemma-9b", smoke=True, max_cache=64)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    t_long = cfg.window + 9  # force wraparound
    full_in = _inputs(cfg, key, t_long + 1)
    positions = jnp.broadcast_to(
        jnp.arange(t_long + 1), (B, t_long + 1)
    )
    ref_logits, _, _ = forward(
        params, cfg, full_in, positions=positions, mode="train"
    )
    _, cache = prefill(params, cfg, full_in[:, :t_long])
    _, _, dec = decode_step(
        params, cfg, cache, full_in[:, t_long:], jnp.int32(t_long)
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(ref_logits[:, t_long]),
        rtol=3e-2, atol=3e-2,
    )


def test_param_count_analytic_matches_actual():
    for name in ARCH_NAMES:
        cfg = get_config(name, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert 0.85 < est / actual < 1.15, (name, est, actual)
