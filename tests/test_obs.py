"""repro.obs acceptance: spans, exporters, metrics, drift (ISSUE 9).

Pins the observability contract:

  * span nesting / thread lanes / exception recording are exact under
    a fake clock (no ``time.*`` in any assertion);
  * the Chrome trace exporter emits deterministic, schema-valid JSON
    (validated against the checked-in ``chrome_trace.schema.json``,
    which also rejects malformed documents);
  * the Prometheus exposition is byte-deterministic;
  * the drift report joins measured vs modeled per phase, dedups
    nested same-phase spans, and prices a real ``Reconstructor`` with
    the same decomposition the autotuner sums;
  * a traced streaming drain agrees with ``StreamResult``'s ``*_s``
    fields to <1% (they are the same span durations by construction);
  * a failed serve job still carries terminal telemetry and its
    failing span records the exception type;
  * the deprecated ``*_seconds`` aliases are gone (the one-release
    window closed; only the ``*_s`` names remain).
"""
import json
import threading

import numpy as np
import pytest

from repro.obs import drift, export, metrics, trace


def fake_clock(*vals):
    return iter([float(v) for v in vals]).__next__


def counting_clock():
    it = iter(range(10_000))
    return lambda: float(next(it))


# --------------------------------------------------------------------- #
# trace: spans
# --------------------------------------------------------------------- #
def test_span_nesting_exact_under_fake_clock():
    t = trace.Tracer(enabled=True, clock=counting_clock())
    with t.span("stream/slab", slab=3) as outer:
        with t.span("stream/solve") as inner:
            pass
    # children close (and record) before parents; parent/depth tracked
    assert [(e["name"], e["t0"], e["t1"], e["depth"], e["parent"])
            for e in t.events] == [
        ("stream/solve", 1.0, 2.0, 1, "stream/slab"),
        ("stream/slab", 0.0, 3.0, 0, None),
    ]
    assert inner.duration_s == 1.0 and outer.duration_s == 3.0
    assert t.events[1]["attrs"] == {"slab": 3}
    assert t.total_s("stream/solve") == 1.0
    assert len(t.spans("stream/slab")) == 1


def test_disabled_tracer_measures_but_records_nothing():
    t = trace.Tracer(enabled=False, clock=fake_clock(5.0, 7.5))
    with t.span("stream/solve") as sp:
        pass
    assert sp.duration_s == 2.5  # callers still get their timing
    assert t.events == []
    t.instant("recon/exchange", ici_bytes=1)
    assert t.events == []


def test_span_records_exception_type_and_still_measures():
    t = trace.Tracer(enabled=True, clock=fake_clock(0.0, 1.0))
    with pytest.raises(KeyError):
        with t.span("serve/slab", job=7) as sp:
            raise KeyError("boom")
    assert sp.duration_s == 1.0
    (e,) = t.events
    assert e["attrs"] == {"job": 7, "exception": "KeyError"}


def test_thread_lanes_are_separate():
    t = trace.Tracer(enabled=True, clock=counting_clock())
    with t.span("stream/solve"):
        pass

    def worker():
        with t.span("stream/load"):
            pass

    th = threading.Thread(target=worker, name="prefetch-0")
    th.start()
    th.join()
    by_name = {e["name"]: e for e in t.events}
    load, solve = by_name["stream/load"], by_name["stream/solve"]
    assert load["thread"] == "prefetch-0"
    assert load["thread_id"] != solve["thread_id"]
    # the worker's span is top-of-stack on ITS OWN thread, not nested
    # under whatever the main thread had open
    assert load["parent"] is None and load["depth"] == 0
    doc = export.chrome_trace(t)
    tids = {e["name"]: e["tid"] for e in doc["traceEvents"]
            if e["ph"] == "X"}
    assert tids["stream/load"] != tids["stream/solve"]


def test_explicit_lane_groups_events():
    t = trace.Tracer(enabled=True, clock=counting_clock())
    with t.span("serve/slab", lane="tenant:alice"):
        pass
    with t.span("serve/slab", lane="tenant:bob"):
        pass
    doc = export.chrome_trace(t)
    lanes = {e["args"]["name"]: e["tid"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert set(lanes) == {"tenant:alice", "tenant:bob"}
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["tid"] for e in xs} == set(lanes.values())


# --------------------------------------------------------------------- #
# export: schema + determinism
# --------------------------------------------------------------------- #
def _small_tracer():
    t = trace.Tracer(enabled=True, clock=fake_clock(10.0, 11.0, 11.5))
    with t.span("stream/solve", slab=0):
        pass
    t.instant("recon/exchange", ici_bytes=128.0, dci_bytes=0.0)
    return t


def test_chrome_trace_schema_valid_and_deterministic(tmp_path):
    doc = export.validate_chrome_trace(export.chrome_trace(_small_tracer()))
    # timestamps rebase to the earliest event; micros
    (x,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert x["ts"] == 0.0 and x["dur"] == pytest.approx(1e6)
    (i,) = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert i["ts"] == pytest.approx(1.5e6) and i["s"] == "t"
    # identical tracers -> byte-identical files
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    export.write_chrome_trace(str(p1), _small_tracer())
    export.write_chrome_trace(str(p2), _small_tracer())
    assert p1.read_bytes() == p2.read_bytes()
    export.validate_chrome_trace(json.loads(p1.read_text()))


def test_schema_rejects_malformed_documents():
    good = export.chrome_trace(_small_tracer())
    with pytest.raises(export.SchemaError, match="missing required"):
        export.validate_chrome_trace({"traceEvents": []})
    with pytest.raises(export.SchemaError, match="not in"):
        bad = json.loads(json.dumps(good))
        bad["traceEvents"][0]["ph"] = "Q"
        export.validate_chrome_trace(bad)
    with pytest.raises(export.SchemaError, match="minimum"):
        bad = json.loads(json.dumps(good))
        bad["traceEvents"][-1]["ts"] = -1.0
        export.validate_chrome_trace(bad)
    with pytest.raises(export.SchemaError, match="expected integer"):
        bad = json.loads(json.dumps(good))
        bad["traceEvents"][0]["tid"] = "one"
        export.validate_chrome_trace(bad)
    with pytest.raises(export.SchemaError, match="missing ts/dur"):
        bad = json.loads(json.dumps(good))
        for e in bad["traceEvents"]:
            if e["ph"] == "X":
                del e["dur"]
        export.validate_chrome_trace(bad)


# --------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------- #
def test_metrics_exposition_is_deterministic():
    def build():
        m = metrics.Metrics()
        m.inc("serve_jobs_total", 2, status="done")
        m.inc("serve_jobs_total", status="failed")
        m.set_gauge("serve_queue_depth", 4)
        m.observe("batch_s", 0.05, buckets=(0.01, 0.1, 1.0))
        m.observe("batch_s", 0.5, buckets=(0.01, 0.1, 1.0))
        return m

    text = build().render_prometheus()
    assert text == build().render_prometheus()
    assert 'serve_jobs_total{status="done"} 2' in text
    assert "# TYPE batch_s histogram" in text
    # cumulative buckets: 0.05 lands in le=0.1 AND le=1
    assert 'batch_s_bucket{le="0.1"} 1' in text
    assert 'batch_s_bucket{le="1"} 2' in text
    assert 'batch_s_bucket{le="+Inf"} 2' in text
    assert build().get("serve_jobs_total", status="done") == 2.0
    assert build().get("nope") == 0.0


def test_counters_cannot_decrease():
    m = metrics.Metrics()
    with pytest.raises(ValueError, match="cannot decrease"):
        m.inc("x_total", -1)


# --------------------------------------------------------------------- #
# drift
# --------------------------------------------------------------------- #
def test_drift_report_pins_on_injected_model():
    t = trace.Tracer(enabled=True, clock=fake_clock(0.0, 2.0, 2.0, 2.5))
    with t.span("stream/solve"):
        pass
    with t.span("stream/load"):
        pass
    rep = drift.drift_report(
        t,
        modeled={"solve": 1.0, "hbm": 0.5, "dma_issue": 0.3,
                 "exchange_ici": 0.2, "exchange_dci": 0.0},
        threshold=0.5,
    )
    assert [r.phase for r in rep.rows] == list(drift.PHASES)
    solve = rep.row("solve")
    assert (solve.measured_s, solve.modeled_s, solve.ratio,
            solve.source, solve.flagged) == (2.0, 1.0, 2.0, "span", True)
    # sub-phases: attributed share of the measured solve, never flagged
    hbm = rep.row("hbm")
    assert hbm.measured_s == pytest.approx(1.0)
    assert hbm.share == pytest.approx(0.5)
    assert hbm.source == "attributed" and not hbm.flagged
    assert rep.row("exchange_dci").ratio is None  # modeled 0: no ratio
    assert rep.row("load").measured_s == 0.5
    assert rep.row("load").modeled_s is None
    assert [r.phase for r in rep.flagged] == ["solve"]
    # a measured solve inside the band does not flag
    t2 = trace.Tracer(enabled=True, clock=fake_clock(0.0, 1.2))
    with t2.span("stream/solve"):
        pass
    rep2 = drift.drift_report(t2, modeled={"solve": 1.0}, threshold=0.5)
    assert rep2.flagged == []
    # render + json round out the report object
    assert "DRIFT" in rep.render()
    parsed = json.loads(rep.to_json())
    assert parsed["rows"][0]["phase"] == "solve"


def test_drift_dedups_nested_same_phase_spans():
    t = trace.Tracer(enabled=True, clock=counting_clock())
    with t.span("stream/solve"):        # 0 .. 3
        with t.span("recon/solve"):     # 1 .. 2: same phase, nested
            pass
    measured = drift.measured_phases(t)
    assert measured == {"solve": 3.0}  # NOT 3 + 1
    # the same inner span at top level DOES count
    t2 = trace.Tracer(enabled=True, clock=fake_clock(0.0, 1.0))
    with t2.span("recon/solve"):
        pass
    assert drift.measured_phases(t2) == {"solve": 1.0}


def test_drift_requires_model_or_reconstructor():
    t = trace.Tracer(enabled=True)
    with pytest.raises(ValueError, match="modeled= or all of"):
        drift.drift_report(t)


def test_modeled_phases_prices_real_reconstructor(small_system):
    from repro.core.recon import ReconConfig, Reconstructor

    _, _, plan = small_system
    rec = Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )
    phases, meta = drift.modeled_phases(rec, iters=4, n_slices=8)
    # the same decomposition the autotuner's modeled tier sums
    assert phases["solve"] == pytest.approx(
        phases["hbm"] + phases["dma_issue"]
        + phases["exchange_ici"] + phases["exchange_dci"]
    )
    assert phases["hbm"] > 0 and phases["dma_issue"] > 0
    assert meta["overhead_source"] == "default"
    assert meta["per_copy_overhead_s"] > 0
    # iters scale linearly in applications: (iters+1)
    p2, _ = drift.modeled_phases(rec, iters=9, n_slices=8)
    assert p2["solve"] == pytest.approx(phases["solve"] * 2.0)
    # a calibrated overhead changes only the issue term + provenance
    p3, m3 = drift.modeled_phases(
        rec, iters=4, n_slices=8,
        per_copy_overhead_s=2 * meta["per_copy_overhead_s"],
    )
    assert p3["dma_issue"] == pytest.approx(2 * phases["dma_issue"])
    assert p3["hbm"] == phases["hbm"]
    assert m3["overhead_source"] == "measured"
    with pytest.raises(ValueError, match="granule"):
        drift.modeled_phases(rec, iters=4, n_slices=7)


# --------------------------------------------------------------------- #
# wired paths: streaming + serve
# --------------------------------------------------------------------- #
@pytest.fixture()
def fresh_tracer():
    """Swap in an enabled tracer + fresh metrics; restore after."""
    old_t = trace.set_tracer(trace.Tracer(enabled=True))
    old_m = metrics.set_metrics(metrics.Metrics())
    try:
        yield trace.get_tracer(), metrics.get_metrics()
    finally:
        trace.set_tracer(old_t)
        metrics.set_metrics(old_m)


def test_streaming_trace_agrees_with_result_fields(
    small_system, tmp_path, fresh_tracer
):
    from repro.core.recon import ReconConfig, Reconstructor
    from repro.data.phantom import phantom_slices, simulate_measurements
    from repro.stream import (
        SlabStore,
        reconstruct_streaming,
        simulate_to_store,
    )

    tracer, m = fresh_tracer
    geo, a, plan = small_system
    rec = Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )
    store = SlabStore.create(str(tmp_path / "sino"), geo.n_rays, 8, 2)
    simulate_to_store(a, geo.n, store, noise=0.01, seed=5)
    res = reconstruct_streaming(
        rec, store, str(tmp_path / "vol"), iters=3, y_slab=4,
    )
    assert len(res.solved) == 2
    # acceptance: per-slab span sums agree with the result fields to
    # <1% -- by construction they are the SAME span durations
    for name, field in (
        ("stream/solve", res.solve_s),
        ("stream/load", res.load_s),
        ("stream/stage", res.upload_s),
        ("stream/slab", res.slab_s),
    ):
        assert tracer.total_s(name) == pytest.approx(
            sum(field), rel=0.01
        ), name
    # exchange instants + counters rode along
    ex = [e for e in tracer.events if e["name"] == "recon/exchange"]
    assert len(ex) == 2 and all(
        e["attrs"]["ici_bytes"] > 0 for e in ex
    )
    assert m.get("stream_slabs_total") == 2.0
    assert m.get("comm_bytes_total", link="ici") == pytest.approx(
        sum(e["attrs"]["ici_bytes"] for e in ex)
    )
    assert m.get("dma_issues_total", op="spmm") > 0
    # the whole trace exports schema-valid
    export.validate_chrome_trace(export.chrome_trace(tracer))
    # and the drift report covers the acceptance phases from a live rec
    rep = drift.drift_report(tracer, rec=rec, iters=3, n_slices=8)
    assert rep.row("solve").source == "span"
    assert rep.row("dma_issue").source == "attributed"
    assert rep.row("exchange_ici").source == "attributed"


def test_failed_serve_job_reports_terminal_telemetry(
    small_system, tmp_path, fresh_tracer
):
    from repro.core.partition import PartitionConfig
    from repro.core.recon import ReconConfig
    from repro.data.phantom import phantom_slices, simulate_measurements
    from repro.serve import JobSpec, ReconServer
    from repro.stream import SlabStore

    tracer, m = fresh_tracer
    geo, a, _ = small_system
    x = phantom_slices(geo.n, 8, seed=5)
    sino = simulate_measurements(a, x, noise=0.01, seed=5)
    pcfg = PartitionConfig(
        n_data=1, tile=4, rows_per_block=16, nnz_per_stage=16
    )
    rcfg = ReconConfig(precision="single", comm_mode="rs", fuse=2)
    # a sinogram store missing its second shard: slab 1 solves, slab 2's
    # fetch raises inside the stream/load span
    holey = SlabStore.create(str(tmp_path / "holey"), geo.n_rays, 8, 4)
    holey.write(0, sino[:, :4])
    srv = ReconServer(2 * 2**30, workdir=str(tmp_path / "srv"))
    bad = srv.submit(JobSpec(geo=geo, sino=holey, pcfg=pcfg, rcfg=rcfg,
                             iters=3, y_slab=4))
    srv.drain()
    assert bad.status == "failed"
    t = bad.telemetry
    # the telemetry gap, closed: a failed job still reports terminal
    # timing and what killed it, plus the split up to the failure point
    assert t.total_s > 0
    assert t.error_type == "FileNotFoundError"
    assert t.n_slabs == 1 and t.solve_s > 0
    # the failing span recorded the exception type
    failed_loads = [
        e for e in tracer.spans("stream/load")
        if "exception" in e["attrs"]
    ]
    assert [e["attrs"]["exception"] for e in failed_loads] == [
        "FileNotFoundError"
    ]
    # slabs that DID run sit on the tenant lane
    assert tracer.spans("serve/slab")[0]["lane"] == "tenant:default"
    assert m.get("serve_jobs_total", status="failed") == 1.0
    assert m.get("plan_cache_misses_total") == 1.0
    # the server's scrape endpoint renders the same registry
    text = srv.metrics_text()
    assert 'serve_jobs_total{status="failed"} 1' in text
    assert "serve_queue_depth 0" in text


def test_seconds_aliases_are_gone():
    """The deprecated ``*_seconds`` aliases completed their one-release
    deprecation window: only the ``*_s`` names remain."""
    from repro.serve.jobs import JobTelemetry
    from repro.stream.driver import StreamResult

    res = StreamResult(
        volume=None, resnorms=np.zeros((1, 1)), y_slab=4,
        solved=[0], skipped=[], slab_s=[1.5],
    )
    assert not hasattr(res, "slab_seconds")
    assert not hasattr(JobTelemetry(), "queue_seconds")
