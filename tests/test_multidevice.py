"""Distributed correctness on 8 virtual host devices (subprocess -- the
device count must be set before jax initializes, so these run out of
process)."""
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import Reconstructor, ReconConfig
geo = XCTGeometry(n=32, n_angles=48)
A = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=4, tile=4,
                  rows_per_block=16, nnz_per_stage=16), a=A)
mesh = jax.make_mesh((2, 4), ("data", "model"),
    axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(0)
Y = 8
x_true = rng.random((geo.n_vox, Y)).astype(np.float32)
sino = (A @ x_true).astype(np.float32)
"""


@pytest.mark.parametrize(
    "mode", ["direct", "rs", "hier", "sparse", "hier-sparse"]
)
def test_comm_modes_match_scipy(mode):
    _run(
        _COMMON
        + f"""
rec = Reconstructor(plan, mesh=mesh, data_axes=("model",),
    batch_axes=("data",),
    cfg=ReconConfig(precision="single", comm_mode={mode!r}, fuse=2))
yhat = rec.project(x_true)
err = np.abs(yhat - sino).max() / np.abs(sino).max()
assert err < 1e-4, ("project", err)
bt = rec.backproject(sino)
ref = A.T @ sino
err = np.abs(bt - ref).max() / np.abs(ref).max()
assert err < 1e-4, ("backproject", err)
print("OK", {mode!r})
"""
    )


def test_multiaxis_data_parallel_recon():
    _run(
        _COMMON
        + """
plan8 = build_plan(geo, PartitionConfig(n_data=8, tile=4,
                   rows_per_block=16, nnz_per_stage=16), a=A)
rec = Reconstructor(plan8, mesh=mesh, data_axes=("model", "data"),
    batch_axes=(),
    cfg=ReconConfig(precision="mixed", comm_mode="hier", fuse=2))
x, res = rec.reconstruct(sino, iters=15)
rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(x_true, axis=0)
# random image, 15 iters: machinery-equivalence check, not a rate test
assert rel.mean() < 0.3, rel
assert res[-1, 0] < 0.2 * res[0, 0]
print("OK multiaxis", rel.mean())
"""
    )


def test_hier_equals_direct_distributed():
    """Hierarchical staging is numerically identical to direct reduction
    in fp32 (the paper's optimization is schedule-only)."""
    _run(
        _COMMON
        + """
outs = []
for mode in ("direct", "hier"):
    rec = Reconstructor(plan, mesh=mesh, data_axes=("model",),
        batch_axes=("data",),
        cfg=ReconConfig(precision="single", comm_mode=mode, fuse=2))
    x, _ = rec.reconstruct(sino, iters=5)
    outs.append(x)
assert np.allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
print("OK hier==direct")
"""
    )


def test_socket_layout_matches_scipy_distributed():
    """Hilbert-aware socket linearization (PartitionConfig.socket) is a
    pure relabeling: every comm mode must still reproduce scipy exactly
    on a 2-wide-socket x 2-node ladder."""
    _run(
        _COMMON
        + """
from repro.dist import Topology
plan_s = build_plan(geo, PartitionConfig(n_data=4, tile=4,
                    rows_per_block=16, nnz_per_stage=16, socket=2), a=A)
mesh2 = jax.make_mesh((2, 2, 2), ("model", "data", "rest"))
topo = Topology.from_mesh(mesh2, data_axes=("model", "data"),
                          batch_axes=("rest",))
for mode in ("hier", "hier-sparse"):
    rec = Reconstructor(plan_s, topology=topo,
        cfg=ReconConfig(precision="single", comm_mode=mode, fuse=2))
    yhat = rec.project(x_true)
    err = np.abs(yhat - sino).max() / np.abs(sino).max()
    assert err < 1e-4, (mode, "project", err)
    bt = rec.backproject(sino)
    ref = A.T @ sino
    err = np.abs(bt - ref).max() / np.abs(ref).max()
    assert err < 1e-4, (mode, "backproject", err)
print("OK socket layout")
"""
    )


def test_hier_train_step_multidevice():
    """LM: hierarchical bf16 grad sync across a real 2x2x2 mesh matches
    the spmd step within wire precision."""
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models.lm import make_train_step, make_hier_train_step
from repro.models.transformer import init_params
from repro.dist.sharding import param_specs, shardings
from repro.opt.adam import AdamW
cfg = get_config("smollm-135m", smoke=True)
opt = AdamW(lr=1e-3, grad_clip=0.0)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
    axis_types=(jax.sharding.AxisType.Auto,)*3)
params = init_params(cfg, jax.random.PRNGKey(0))
pspecs = param_specs(params, mesh)
params = jax.device_put(params, shardings(pspecs, mesh))
stream = TokenStream(cfg.vocab_size, 16, 8, seed=2)
batch = stream.batch(0)
batch = jax.device_put(batch, NamedSharding(mesh, P(("pod", "data"))))
p1, _, m1 = jax.jit(make_train_step(cfg, opt))(params, opt.init(params), batch)
p2, _, m2 = jax.jit(make_hier_train_step(cfg, opt, mesh))(params, opt.init(params), batch)
# 2e-3: off-TPU the hier step runs fully manual (no auto-TP), so bf16
# contractions group differently from the spmd step
assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
err = max(float(jnp.max(jnp.abs(a - b)))
          for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert err < 5e-3, err
print("OK hier train", float(m1["loss"]), err)
"""
    )


def test_remesh_checkpoint_roundtrip():
    """Elastic restart: params saved from a (2,2,2) mesh restore onto a
    (1,2,4) mesh with identical values."""
    _run(
        """
import tempfile, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.transformer import init_params
from repro.dist.sharding import param_specs, shardings
from repro.ckpt.checkpoint import save, restore
from repro.dist.fault import remesh
cfg = get_config("smollm-135m", smoke=True)
params = init_params(cfg, jax.random.PRNGKey(3))
mesh1 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
    axis_types=(jax.sharding.AxisType.Auto,)*3)
p1 = jax.device_put(params, shardings(param_specs(params, mesh1), mesh1))
d = tempfile.mkdtemp()
save(d, 1, p1)
mesh2 = jax.make_mesh((1, 2, 4), ("pod", "data", "model"),
    axis_types=(jax.sharding.AxisType.Auto,)*3)
like = jax.eval_shape(lambda: params)
restored = restore(d, 1, like)
p2 = remesh(restored, param_specs(params, mesh2), mesh2)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK remesh")
"""
    )
