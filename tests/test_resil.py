"""Chaos acceptance (ISSUE 10): injection, retry, quarantine, self-heal.

Pins the tentpole criteria:

  * a streaming run under a seeded ``FaultPlan`` (one transient read
    error, one corrupt shard, one injected NaN) completes with a volume
    BIT-IDENTICAL to the clean run, with ``retries > 0``;
  * when retries are exhausted, exactly the poison slab is quarantined
    (``StreamResult.failed_slabs`` + ``slabs_quarantined_total``), the
    drain finishes the rest, and a later resume re-attempts it;
  * a non-finite quantized solve escalates one precision rung and
    succeeds; a dead prefetch worker recovers via the driver's
    synchronous re-try; a flagged straggler shrinks the lookahead;
  * the serve path retries transient loads per job, enforces deadlines,
    and trips a per-plan circuit breaker on repeated build failures;
  * ``obs.drift`` excludes retried attempts from the model join.
"""
import os
import time

import numpy as np
import pytest

from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices, simulate_measurements
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.resil import (
    CircuitBreaker,
    CorruptShardError,
    FaultPlan,
    InjectedIOError,
    InjectedThreadDeath,
    NonFiniteSolveError,
    RetryPolicy,
    call_with_retry,
    inject,
)
from repro.resil.inject import hash01
from repro.stream import SlabStore, reconstruct_streaming, simulate_to_store

Y = 8  # slices in the streaming fixtures (multiple of fuse=2)


@pytest.fixture(scope="module")
def rec(small_system):
    _, _, plan = small_system
    return Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )


@pytest.fixture(scope="module")
def sino8(small_system):
    geo, a, _ = small_system
    x = phantom_slices(geo.n, Y, seed=5)
    return simulate_measurements(a, x, noise=0.01, seed=5)


@pytest.fixture()
def sino_store(small_system, tmp_path):
    geo, a, _ = small_system
    store = SlabStore.create(str(tmp_path / "sino"), geo.n_rays, Y, 2)
    simulate_to_store(a, geo.n, store, noise=0.01, seed=5)
    return store


@pytest.fixture()
def fresh_obs():
    """Isolated metrics + tracer so counter asserts see only this test."""
    old_t = obs_trace.set_tracer(obs_trace.Tracer(enabled=True))
    old_m = obs_metrics.set_metrics(obs_metrics.Metrics())
    try:
        yield obs_trace.get_tracer(), obs_metrics.get_metrics()
    finally:
        obs_trace.set_tracer(old_t)
        obs_metrics.set_metrics(old_m)


FAST = RetryPolicy(max_attempts=3, base_delay_s=0.0, jitter=0.0)


# --------------------------------------------------------------------- #
# injection registry
# --------------------------------------------------------------------- #
def test_hash01_deterministic_in_range():
    a = hash01(0, "site", 3, 1)
    assert a == hash01(0, "site", 3, 1)
    assert 0.0 <= a < 1.0
    # any argument perturbs the draw
    assert a != hash01(1, "site", 3, 1)
    assert a != hash01(0, "site", 3, 2)


def test_inactive_sites_are_passthrough():
    arr = np.ones(4, np.float32)
    assert inject.mutate("store/read", arr, key=0) is arr  # same object
    inject.fire("stream/load", key=0)  # no-op, no error
    assert not inject.active()


def test_transient_vs_persistent_attempts(fresh_obs):
    _, m = fresh_obs
    plan = (
        FaultPlan(seed=1)
        .add("stream/load", "io_error", key=2, attempts=(0,))
        .add("stream/stage", "io_error", key=7, attempts=None)
    )
    with inject.activate(plan) as h:
        with pytest.raises(InjectedIOError):
            inject.fire("stream/load", key=2)
        inject.fire("stream/load", key=2)  # attempt 1: healed
        inject.fire("stream/load", key=3)  # other key: never fires
        for _ in range(3):  # persistent: every consultation fires
            with pytest.raises(InjectedIOError):
                inject.fire("stream/stage", key=7)
    assert [f[:3] for f in h.fired] == [
        ("stream/load", 2, 0),
        ("stream/stage", 7, 0),
        ("stream/stage", 7, 1),
        ("stream/stage", 7, 2),
    ]
    assert m.get(
        "faults_injected_total", site="stream/load", kind="io_error"
    ) == 1
    assert not inject.active()  # deactivated on exit


def test_ctx_match_scope_and_mutations(fresh_obs):
    plan = (
        FaultPlan(seed=3)
        .add("recon/solve", "nonfinite", attempts=None,
             when={"precision": "q8"})
        .add("store/read", "corrupt", key=0, attempts=(0,))
    )
    x = np.arange(8, dtype=np.float32)
    with inject.activate(plan):
        with inject.scope(5):  # keyless site resolves via scope
            bad = inject.mutate("recon/solve", x, ctx={"precision": "q8"})
            ok = inject.mutate(
                "recon/solve", x, ctx={"precision": "single"}
            )
        assert np.isnan(bad).sum() == 1 and bad is not x  # copy poisoned
        assert np.isfinite(x).all()  # caller's array untouched
        assert np.array_equal(ok, x)
        flipped = inject.mutate("store/read", x, key=0)
        assert (flipped != x).sum() == 1  # one byte-flipped element
    # replaying the same plan fires identically (counters reset)
    with inject.activate(plan):
        with inject.scope(5):
            again = inject.mutate(
                "recon/solve", x, ctx={"precision": "q8"}
            )
        np.testing.assert_array_equal(again, bad)  # same element poisoned


def test_activate_is_exclusive():
    with inject.activate(FaultPlan()):
        with pytest.raises(RuntimeError, match="already active"):
            with inject.activate(FaultPlan()):
                pass


# --------------------------------------------------------------------- #
# retry policy
# --------------------------------------------------------------------- #
def test_retry_backoff_deterministic_and_bounded():
    p = RetryPolicy(base_delay_s=0.1, backoff=2.0, jitter=0.5, seed=3)
    d = [p.delay_s("stream/load", 4, a) for a in (1, 2, 3)]
    assert d == [p.delay_s("stream/load", 4, a) for a in (1, 2, 3)]
    for a, nominal in zip((1, 2, 3), (0.1, 0.2, 0.4)):
        assert 0.5 * nominal <= d[a - 1] <= 1.5 * nominal
    # different keys de-synchronize two workers' backoff
    assert p.delay_s("stream/load", 4, 1) != p.delay_s("stream/load", 5, 1)


def test_call_with_retry_transient_then_success(fresh_obs):
    _, m = fresh_obs
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise OSError("disk hiccup")
        return "ok"

    assert call_with_retry(
        flaky, policy=FAST, site="stream/load", key=1
    ) == "ok"
    assert calls == [0, 1, 2]
    assert m.get("retries_total", site="stream/load") == 2


def test_call_with_retry_exhaustion_reraises_last():
    def dead(attempt):
        raise OSError(f"gone {attempt}")

    with pytest.raises(OSError, match="gone 2"):
        call_with_retry(dead, policy=FAST, site="s", sleep=lambda d: None)


def test_corrupt_shard_retried_exactly_once():
    calls = []

    def corrupt(attempt):
        calls.append(attempt)
        raise CorruptShardError("crc mismatch")

    with pytest.raises(CorruptShardError):
        call_with_retry(corrupt, policy=FAST, site="store/read")
    assert calls == [0, 1]  # one re-read, not max_attempts


def test_nonretryable_propagates_immediately():
    with pytest.raises(ValueError):
        call_with_retry(
            lambda a: (_ for _ in ()).throw(ValueError("bug")),
            policy=FAST, site="s",
        )


def test_retry_timeout_budget():
    t = {"n": 0}

    def slow(attempt):
        t["n"] += 1
        time.sleep(0.05)
        raise OSError("still down")

    p = RetryPolicy(max_attempts=100, base_delay_s=0.0, timeout_s=0.01)
    with pytest.raises(OSError):
        call_with_retry(slow, policy=p, site="s")
    assert t["n"] <= 2  # budget cut it off long before 100 attempts


# --------------------------------------------------------------------- #
# store integrity
# --------------------------------------------------------------------- #
def test_store_records_and_verifies_checksums(tmp_path):
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((6, 8)).astype(np.float32)
    store = SlabStore.from_array(str(tmp_path / "s"), arr, slab=4)
    import json

    with open(tmp_path / "s" / "manifest.json") as f:
        man = json.load(f)
    assert man["checksum_algo"] == "crc32"
    assert set(man["checksums"]) == {"0_4", "4_8"}
    # re-open (create with matching shape) keeps the recorded checksums
    again = SlabStore.create(str(tmp_path / "s"), 6, 8, 4)
    assert again._checksums == {
        k: int(v) for k, v in man["checksums"].items()
    }
    np.testing.assert_array_equal(again.to_array(), arr)


def test_store_detects_on_disk_corruption(tmp_path):
    arr = np.ones((4, 4), np.float32)
    store = SlabStore.from_array(str(tmp_path / "s"), arr, slab=4)
    path = store._shard_path(0, 4)
    with open(path, "r+b") as f:  # flip one payload byte on disk
        f.seek(-3, os.SEEK_END)
        b = f.read(1)
        f.seek(-3, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    fresh = SlabStore.open(str(tmp_path / "s"))
    with pytest.raises(CorruptShardError, match="crc"):
        fresh.read(0, 4)
    # a re-write replaces the shard and its recorded crc: reads heal
    fresh.write(0, arr)
    np.testing.assert_array_equal(fresh.read(0, 4), arr)


def test_store_verify_cache_bypassed_while_injecting(tmp_path):
    arr = np.full((3, 2), 7.0, np.float32)
    store = SlabStore.from_array(str(tmp_path / "s"), arr, slab=2)
    np.testing.assert_array_equal(store.read(0, 2), arr)  # verified+cached
    plan = FaultPlan(seed=2).add(
        "store/read", "corrupt", key=0, attempts=(0,)
    )
    with inject.activate(plan):
        with pytest.raises(CorruptShardError):
            store.read(0, 2)  # cache must not mask the injected flip
        np.testing.assert_array_equal(store.read(0, 2), arr)  # healed
    np.testing.assert_array_equal(store.read(0, 2), arr)


# --------------------------------------------------------------------- #
# streaming chaos scenarios (tentpole acceptance)
# --------------------------------------------------------------------- #
def test_streaming_transient_faults_bit_exact(
    rec, sino_store, tmp_path, fresh_obs
):
    """One transient read error + one corrupt shard + one injected NaN:
    the drain absorbs all three and the volume is BIT-IDENTICAL to the
    clean run's."""
    _, m = fresh_obs
    clean = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "clean"), iters=6, y_slab=2
    )
    plan = (
        FaultPlan(seed=7)
        .add("store/read", "io_error", key=0, attempts=(0,))
        .add("store/read", "corrupt", key=4, attempts=(0,))
        .add("recon/solve", "nonfinite", key=1, attempts=(0,))
    )
    with inject.activate(plan) as h:
        chaos = reconstruct_streaming(
            rec, sino_store, str(tmp_path / "chaos"), iters=6, y_slab=2,
            retry=FAST,
        )
    assert chaos.complete and chaos.failed_slabs == []
    assert chaos.retries >= 3  # each fault cost at least one retry
    kinds = sorted(f[3] for f in h.fired)
    assert kinds == ["corrupt", "io_error", "nonfinite"]
    np.testing.assert_array_equal(
        chaos.volume.to_array(), clean.volume.to_array()
    )
    np.testing.assert_array_equal(chaos.resnorms, clean.resnorms)
    assert m.get("retries_total", site="stream/load") >= 1
    assert m.get("retries_total", site="stream/solve") >= 1
    assert m.get(
        "faults_injected_total", site="store/read", kind="io_error"
    ) == 1


def test_streaming_quarantines_poison_slab_and_resumes(
    rec, sino_store, tmp_path, fresh_obs
):
    """Retries exhausted on one shard: exactly that slab is quarantined,
    the rest completes bit-exact, and a resume (fault gone) finishes the
    volume identically to a clean run."""
    _, m = fresh_obs
    clean = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "clean"), iters=6, y_slab=2
    )
    plan = FaultPlan(seed=11).add(
        "store/read", "io_error", key=4, attempts=None  # persistent
    )
    ck = str(tmp_path / "ck")
    with inject.activate(plan):
        part = reconstruct_streaming(
            rec, sino_store, str(tmp_path / "vol"), iters=6, y_slab=2,
            retry=FAST, ckpt_dir=ck,
        )
    assert part.failed_slabs == [4]  # exactly the poison slab
    assert not part.complete
    assert sorted(part.solved) == [0, 2, 6]  # drain continued past it
    assert part.retries > 0
    assert m.get("slabs_quarantined_total") == 1
    for j0, j1 in clean.volume.slabs():
        if j0 == 4:
            continue
        np.testing.assert_array_equal(
            part.volume.read(j0, j1), clean.volume.read(j0, j1)
        )
    # resume without the fault plan: the quarantined slab is re-attempted
    rest = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "vol"), iters=6, y_slab=2,
        retry=FAST, ckpt_dir=ck,
    )
    assert rest.solved == [4] and rest.complete
    assert sorted(rest.skipped) == [0, 2, 6]
    np.testing.assert_array_equal(
        rest.volume.to_array(), clean.volume.to_array()
    )


def test_streaming_fail_fast_propagates(rec, sino_store, tmp_path):
    plan = FaultPlan(seed=1).add(
        "store/read", "io_error", key=0, attempts=None
    )
    with inject.activate(plan):
        with pytest.raises((InjectedIOError, Exception)) as e:
            reconstruct_streaming(
                rec, sino_store, str(tmp_path / "v"), iters=3, y_slab=2,
                fail_fast=True,
            )
    # the original error is reachable (PrefetchError wraps it)
    exc = e.value
    assert isinstance(exc, InjectedIOError) or isinstance(
        getattr(exc, "cause", exc.__cause__), InjectedIOError
    )


def test_streaming_thread_death_recovers_via_sync_retry(
    rec, sino_store, tmp_path, fresh_obs
):
    """A dying prefetch worker is not retryable in-worker: it surfaces
    as PrefetchError and the driver's one synchronous re-try heals it."""
    _, m = fresh_obs
    clean = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "clean"), iters=5, y_slab=2
    )
    plan = FaultPlan(seed=5).add(
        "stream/load", "thread_death", key=1, attempts=(0,)
    )
    with inject.activate(plan):
        res = reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v"), iters=5, y_slab=2,
            retry=FAST,
        )
    assert res.complete and res.failed_slabs == []
    assert res.retries >= 1
    assert m.get("retries_total", site="stream/slab") == 1
    np.testing.assert_array_equal(
        res.volume.to_array(), clean.volume.to_array()
    )


def test_streaming_nonfinite_escalates_one_rung(
    small_system, sino_store, tmp_path, fresh_obs
):
    """A quantized solve that keeps blowing up re-solves at f32 (the
    `when=` ctx match scopes the poison to the q8 rung) and the drain
    completes without quarantining."""
    _, m = fresh_obs
    _, _, plan = small_system
    rec_q8 = Reconstructor(
        plan, cfg=ReconConfig(precision="q8", comm_mode="rs", fuse=2)
    )
    fplan = FaultPlan(seed=9).add(
        "recon/solve", "nonfinite", key=2, attempts=None,
        when={"precision": "q8"},
    )
    with inject.activate(fplan):
        res = reconstruct_streaming(
            rec_q8, sino_store, str(tmp_path / "v"), iters=5, y_slab=2,
            retry=RetryPolicy(max_attempts=2, base_delay_s=0.0),
        )
    assert res.complete and res.failed_slabs == []
    assert res.escalated == [4]  # slab index 2 -> j0=4, solved at f32
    assert m.get("stream_escalations_total") == 1
    # f64 has no rung to escalate to: the same poison quarantines
    rec_f64 = Reconstructor(
        plan, cfg=ReconConfig(precision="double", comm_mode="rs", fuse=2)
    )
    fplan2 = FaultPlan(seed=9).add(
        "recon/solve", "nonfinite", key=2, attempts=None
    )
    with inject.activate(fplan2):
        res2 = reconstruct_streaming(
            rec_f64, sino_store, str(tmp_path / "v2"), iters=5, y_slab=2,
            retry=RetryPolicy(max_attempts=2, base_delay_s=0.0),
        )
    assert res2.failed_slabs == [4] and not res2.complete


def test_streaming_straggler_shrinks_lookahead(
    rec, sino_store, tmp_path, fresh_obs
):
    """A slow slab load (injected stall, way past the robust threshold)
    flags the straggler and the drain drops to synchronous prefetch --
    pinned by the gauge, the result stays bit-exact."""
    _, m = fresh_obs
    clean = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "clean"), iters=4, y_slab=2
    )
    m.reset()  # ms-scale load jitter may flag the clean run too
    plan = FaultPlan(seed=4).add(
        "stream/load", "slow", key=2, attempts=(0,), delay_s=0.5
    )
    with inject.activate(plan):
        res = reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v"), iters=4, y_slab=2,
            retry=FAST, straggler_k_mad=4.0,
        )
    assert res.complete
    assert 2 in res.stragglers
    assert m.get("stream_stragglers_total") == 1
    assert m.get("stream_prefetch_lookahead") == 0.0
    np.testing.assert_array_equal(
        res.volume.to_array(), clean.volume.to_array()
    )


# --------------------------------------------------------------------- #
# crash-resume property (satellite c)
# --------------------------------------------------------------------- #
def test_crash_resume_bit_exact_at_every_slab(rec, sino_store, tmp_path):
    """Kill the drain via injected preemption after EVERY slab k in
    turn; the resumed run must skip exactly the finished slabs and the
    final volume must be bit-identical to the uninterrupted run."""
    from repro.resil import InjectedPreemption

    base = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "base"), iters=4, y_slab=2
    )
    n_slabs = len(base.volume.slabs())
    for k in range(n_slabs):
        out = str(tmp_path / f"v{k}")
        ck = str(tmp_path / f"ck{k}")
        plan = FaultPlan(seed=k).add(
            "stream/after_slab", "preempt", key=k, attempts=(0,)
        )
        with inject.activate(plan):
            with pytest.raises(InjectedPreemption):
                reconstruct_streaming(
                    rec, sino_store, out, iters=4, y_slab=2,
                    ckpt_dir=ck, checkpoint_every=1,
                )
        rest = reconstruct_streaming(
            rec, sino_store, out, iters=4, y_slab=2, ckpt_dir=ck
        )
        assert rest.complete
        assert len(rest.skipped) == k + 1  # slabs 0..k were durable
        assert len(rest.solved) == n_slabs - k - 1
        np.testing.assert_array_equal(
            rest.volume.to_array(), base.volume.to_array()
        )
        np.testing.assert_array_equal(rest.resnorms, base.resnorms)


# --------------------------------------------------------------------- #
# circuit breaker
# --------------------------------------------------------------------- #
def test_circuit_breaker_state_machine():
    t = {"now": 0.0}
    cb = CircuitBreaker(threshold=2, cooldown_s=30.0,
                        clock=lambda: t["now"])
    assert cb.allow("k")
    cb.record_failure("k")
    assert cb.allow("k")  # one failure: still closed
    cb.record_failure("k")
    assert not cb.allow("k")  # threshold: open
    assert cb.allow("other")  # per-key isolation
    t["now"] = 31.0
    assert cb.allow("k")  # cooldown lapsed: half-open probe
    cb.record_failure("k")  # probe failed: re-open immediately
    assert not cb.allow("k")
    t["now"] = 62.0
    assert cb.allow("k")
    cb.record_success("k")  # probe succeeded: closed, counters clear
    cb.record_failure("k")
    assert cb.allow("k")  # needs `threshold` consecutive fails again


# --------------------------------------------------------------------- #
# serve resilience
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def serve_bits(small_system):
    from repro.core.partition import PartitionConfig

    geo, a, _ = small_system
    pcfg = PartitionConfig(
        n_data=1, tile=4, rows_per_block=16, nnz_per_stage=16
    )
    rcfg = ReconConfig(precision="single", comm_mode="rs", fuse=2)
    x = phantom_slices(geo.n, Y, seed=21)
    sino = simulate_measurements(a, x, noise=0.01, seed=21)
    return geo, pcfg, rcfg, sino


def _spec(serve_bits, **kw):
    from repro.serve import JobSpec

    geo, pcfg, rcfg, sino = serve_bits
    kw.setdefault("iters", 3)
    kw.setdefault("y_slab", 4)
    kw.setdefault("sino", sino)
    return JobSpec(geo=geo, pcfg=pcfg, rcfg=rcfg, **kw)


def test_serve_retries_transient_load(serve_bits, tmp_path, fresh_obs):
    from repro.serve import ReconServer

    _, m = fresh_obs
    geo, pcfg, rcfg, sino = serve_bits
    store = SlabStore.from_array(str(tmp_path / "sino"), sino, slab=4)
    srv = ReconServer(2 * 2**30, workdir=str(tmp_path / "srv"))
    spec = _spec(serve_bits, sino=store, retry=FAST)
    plan = FaultPlan(seed=2).add(
        "store/read", "io_error", key=4, attempts=(0,)
    )
    with inject.activate(plan):
        job = srv.submit(spec)
        srv.drain()
    assert job.status == "done"
    assert job.telemetry.retries == 1
    assert m.get("retries_total", site="serve/load") == 1


def test_serve_deadline_fails_job_not_batch(serve_bits, tmp_path):
    from repro.serve import ReconServer

    srv = ReconServer(2 * 2**30, workdir=str(tmp_path / "srv"))
    doomed = srv.submit(_spec(serve_bits, deadline_s=0.0))
    mate = srv.submit(_spec(serve_bits, tenant="b"))
    srv.drain()
    assert doomed.status == "failed"
    assert "deadline" in doomed.error
    assert doomed.telemetry.error_type == "DeadlineExceeded"
    assert mate.status == "done"  # batch mate unaffected


def test_serve_circuit_breaker_trips_and_recovers(serve_bits, tmp_path):
    from repro.serve import ReconServer

    t = {"now": 0.0}
    srv = ReconServer(
        2 * 2**30, workdir=str(tmp_path / "srv"),
        breaker=CircuitBreaker(threshold=2, cooldown_s=30.0,
                               clock=lambda: t["now"]),
    )
    plan = FaultPlan(seed=1).add("serve/build", "error", attempts=None)
    with inject.activate(plan):
        for _ in range(2):  # two failed builds trip the breaker
            j = srv.submit(_spec(serve_bits))
            srv.drain()
            assert j.status == "failed"
            assert "plan build failed" in j.error
        rejected = srv.submit(_spec(serve_bits))
        srv.drain()
    assert rejected.status == "rejected_circuit"
    assert srv.stats()["rejected_circuit"] == 1
    # cooldown lapses and the fault is gone: the probe job closes it
    t["now"] = 31.0
    probe = srv.submit(_spec(serve_bits))
    srv.drain()
    assert probe.status == "done"
    after = srv.submit(_spec(serve_bits))
    srv.drain()
    assert after.status == "done"


# --------------------------------------------------------------------- #
# drift excludes retried attempts
# --------------------------------------------------------------------- #
def test_drift_measured_phases_skip_retried_spans():
    from repro.obs.drift import measured_phases

    events = [
        {"kind": "span", "name": "stream/solve", "t0": 0.0, "t1": 1.0,
         "parent": None, "attrs": {"retry": 0}},
        {"kind": "span", "name": "stream/solve", "t0": 1.0, "t1": 9.0,
         "parent": None, "attrs": {"retry": 1}},  # retried: excluded
        {"kind": "span", "name": "stream/load", "t0": 0.0, "t1": 0.5,
         "parent": None, "attrs": {}},
    ]
    ph = measured_phases(events)
    assert ph["solve"] == 1.0  # only the attempt-0 span counts
    assert ph["load"] == 0.5
