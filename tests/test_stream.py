"""Out-of-core streaming: store integrity, slab parity, budget, resume.

Acceptance pins (ISSUE 4):
  * a streaming solve under a budget smaller than the full
    sinogram+volume working set completes and matches the in-memory
    ``Reconstructor.reconstruct`` slice for slice;
  * a run killed after slab k and restarted skips the finished slabs
    and produces a volume *identical* to an uninterrupted run.
"""
import os

import numpy as np
import pytest

from repro.core.recon import ReconConfig, Reconstructor, StagedSlab
from repro.data.phantom import phantom_slices, simulate_measurements
from repro.stream import (
    PrefetchError,
    Prefetcher,
    SlabStore,
    reconstruct_streaming,
    simulate_to_store,
    suggest_slab,
)

Y = 8  # slices in the streaming fixtures (multiple of fuse=2)


@pytest.fixture(scope="module")
def rec(small_system):
    _, _, plan = small_system
    return Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=2)
    )


@pytest.fixture(scope="module")
def sino8(small_system):
    geo, a, _ = small_system
    x = phantom_slices(geo.n, Y, seed=5)
    return simulate_measurements(a, x, noise=0.01, seed=5)


@pytest.fixture()
def sino_store(small_system, sino8, tmp_path):
    geo, a, _ = small_system
    store = SlabStore.create(str(tmp_path / "sino"), geo.n_rays, Y, 2)
    simulate_to_store(a, geo.n, store, noise=0.01, seed=5)
    return store


# --------------------------------------------------------------------- #
# store
# --------------------------------------------------------------------- #
def test_slab_store_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((13, 10)).astype(np.float32)
    store = SlabStore.from_array(str(tmp_path / "s"), arr, slab=3)
    assert store.slabs() == [(0, 3), (3, 6), (6, 9), (9, 10)]
    assert store.complete()
    np.testing.assert_array_equal(store.to_array(), arr)
    # cross-shard range read
    np.testing.assert_array_equal(store.read(2, 8), arr[:, 2:8])
    # reopen sees the same manifest + data
    again = SlabStore.open(str(tmp_path / "s"))
    np.testing.assert_array_equal(again.read(9, 10), arr[:, 9:])


def test_slab_store_guards(tmp_path):
    store = SlabStore.create(str(tmp_path / "s"), 4, 8, 4)
    with pytest.raises(ValueError):  # unaligned start
        store.write(2, np.zeros((4, 4), np.float32))
    with pytest.raises(ValueError):  # wrong shape
        store.write(0, np.zeros((4, 3), np.float32))
    with pytest.raises(FileNotFoundError):  # unwritten slab
        store.read(0, 4)
    assert not store.complete()
    with pytest.raises(ValueError):  # conflicting re-create
        SlabStore.create(str(tmp_path / "s"), 4, 8, 2)


def test_simulate_to_store_matches_oneshot(small_system, sino_store,
                                           sino8):
    """Slab-by-slab simulation == one-shot, bit for bit (chunk-invariant
    noise streams + slab-ranged phantoms)."""
    np.testing.assert_array_equal(sino_store.to_array(), sino8)


def test_phantom_slab_range_invariant():
    full = phantom_slices(16, 6, seed=2)
    parts = [
        phantom_slices(16, 6, seed=2, start=j, stop=min(j + 4, 6))
        for j in (0, 4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), full)


def test_simulate_chunk_kwarg_invariant(small_system):
    geo, a, _ = small_system
    x = phantom_slices(geo.n, 6, seed=1)
    y1 = simulate_measurements(a, x, noise=0.05, seed=1, chunk=1)
    y64 = simulate_measurements(a, x, noise=0.05, seed=1, chunk=64)
    np.testing.assert_array_equal(y1, y64)


# --------------------------------------------------------------------- #
# scheduler
# --------------------------------------------------------------------- #
def test_suggest_slab_formula_and_guard(small_system, rec):
    _, _, plan = small_system
    topo = rec.topology
    # operator footprint (incl. the winsegs DMA tables) + some slack
    budget = plan.proj.hbm_bytes() + plan.back.hbm_bytes() + 1_000_000
    sp = suggest_slab(plan, rec.cfg, topo, budget, n_slices=Y)
    assert sp.granule == 2 and sp.y_slab % 2 == 0
    assert sp.slab_bytes <= budget
    # 5 host copies + the overlap-staged device sinogram of slab i+1
    per = (
        4 * 5 * (plan.proj.n_rows_pad + plan.proj.n_cols_pad)
        + 4 * plan.proj.n_rows_pad
    )
    assert sp.per_slice_bytes == per
    with pytest.raises(ValueError):  # operator alone overflows
        suggest_slab(plan, rec.cfg, topo, sp.fixed_bytes)
    sync = suggest_slab(
        plan, rec.cfg, topo, budget, n_slices=Y, overlap=False
    )
    assert sync.per_slice_bytes < sp.per_slice_bytes  # one staging copy


def test_prefetcher_orders_and_propagates_errors():
    seen = []

    def fetch(i):
        seen.append(i)
        if i == 3:
            raise RuntimeError("boom")
        return i * 10

    items = [0, 1, 2]
    out = list(Prefetcher(fetch, items, depth=1))
    assert out == [(0, 0), (1, 10), (2, 20)]
    with pytest.raises(RuntimeError, match="boom"):
        list(Prefetcher(fetch, [3], depth=1))
    # disabled -> plain synchronous order
    assert list(Prefetcher(lambda i: i, [5, 6], enabled=False)) == [
        (5, 5), (6, 6),
    ]


def test_prefetcher_error_names_failing_item():
    """Satellite: a dead fetch thread surfaces at the consuming next()
    as PrefetchError carrying the failing item + position -- mid-drain,
    not a hang, and not attributed to the wrong slab."""

    def fetch(i):
        if i == 12:
            raise OSError("disk gone")
        return i

    got = []
    with pytest.raises(PrefetchError, match=r"item 12 .*disk gone") as e:
        for item, val in Prefetcher(fetch, [4, 8, 12, 16], depth=1):
            got.append(item)
    assert got == [4, 8]  # slabs before the failure were delivered
    assert e.value.item == 12 and e.value.index == 2
    assert isinstance(e.value.__cause__, OSError)
    # the synchronous path wraps identically
    with pytest.raises(PrefetchError, match="item 12"):
        list(Prefetcher(fetch, [12], enabled=False))


def test_prefetcher_stage_applies_and_times():
    """The device-stage callable runs in the worker (overlap) and
    inline (sync) with identical results, and per-item load/stage wall
    times are recorded either way (keyed by position, so unhashable or
    duplicated items are fine)."""
    for enabled in (True, False):
        pre = Prefetcher(
            lambda i: i * 10, [1, 1], stage=lambda v: v + 5,
            enabled=enabled,
        )
        assert list(pre) == [(1, 15), (1, 15)]
        assert set(pre.times) == {0, 1}  # positions, not item values
        for t in pre.times.values():
            assert t["load"] >= 0.0 and t["stage"] >= 0.0
    # unhashable items are accepted
    pre = Prefetcher(lambda a: float(a.sum()), [np.zeros(2)], depth=1)
    out = list(pre)
    assert len(out) == 1 and out[0][1] == 0.0 and 0 in pre.times
    # a failing stage is attributed like a failing fetch
    with pytest.raises(PrefetchError, match="item 7"):
        list(Prefetcher(
            lambda i: i, [7],
            stage=lambda v: (_ for _ in ()).throw(ValueError("up")),
        ))


# --------------------------------------------------------------------- #
# driver: parity, budget, resume
# --------------------------------------------------------------------- #
def test_streaming_matches_in_memory_slicewise(
    rec, sino_store, sino8, tmp_path
):
    """Pinned parity: each streamed slab is BIT-identical to the
    in-memory ``Reconstructor.reconstruct`` of that slab, and the
    assembled volume tracks the full-Y in-memory solve (which XLA may
    reassociate per compile shape) to well under the phantom scale."""
    res = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "vol"), iters=8, y_slab=4
    )
    assert res.complete and res.solved == [0, 4]
    for j0, j1 in res.volume.slabs():
        x_mem, r_mem = rec.reconstruct(sino8[:, j0:j1], iters=8)
        np.testing.assert_array_equal(res.volume.read(j0, j1), x_mem)
        np.testing.assert_array_equal(res.resnorms[:, j0:j1], r_mem)
    x_full, _ = rec.reconstruct(sino8, iters=8)
    num = np.linalg.norm(res.volume.to_array() - x_full, axis=0)
    den = np.linalg.norm(x_full, axis=0)
    assert (num / den).max() < 1e-2


def test_streaming_budget_smaller_than_volume_completes(
    rec, small_system, sino_store, sino8, tmp_path
):
    """Acceptance: a budget that cannot hold the full sinogram+volume
    working set still completes, in several slabs, matching in-memory."""
    _, _, plan = small_system
    sp = suggest_slab(plan, rec.cfg, rec.topology, 1 << 40)
    full_need = sp.fixed_bytes + Y * sp.per_slice_bytes
    budget = sp.fixed_bytes + (Y // 2) * sp.per_slice_bytes
    assert budget < full_need
    res = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "vol"), iters=6,
        mem_budget=budget,
    )
    assert res.complete and len(res.solved) >= 2
    assert res.y_slab * res.volume.rows  # sanity
    for j0, j1 in res.volume.slabs():
        x_mem, _ = rec.reconstruct(sino8[:, j0:j1], iters=6)
        np.testing.assert_array_equal(res.volume.read(j0, j1), x_mem)


def test_streaming_resume_skips_and_matches(rec, sino_store, tmp_path):
    """Acceptance: killed after slab k + restarted == uninterrupted,
    identically, with the finished slabs skipped (not re-solved)."""
    base = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "v0"), iters=6, y_slab=2
    )
    ck = str(tmp_path / "ck")
    part = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "v1"), iters=6, y_slab=2,
        ckpt_dir=ck, checkpoint_every=1, max_slabs=2,
    )
    assert part.solved == [0, 2] and not part.complete
    rest = reconstruct_streaming(
        rec, sino_store, str(tmp_path / "v1"), iters=6, y_slab=2,
        ckpt_dir=ck,
    )
    assert rest.skipped == [0, 2]  # finished slabs not re-solved
    assert rest.solved == [4, 6] and rest.complete
    np.testing.assert_array_equal(
        rest.volume.to_array(), base.volume.to_array()
    )
    np.testing.assert_array_equal(rest.resnorms, base.resnorms)
    # guards: mismatched slab size on resume is an error -- from the
    # volume store's manifest (same out dir) or the ckpt manifest
    # (fresh out dir, stale ckpt_dir)
    with pytest.raises(ValueError, match="manifest"):
        reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v1"), iters=6, y_slab=4,
            ckpt_dir=ck,
        )
    with pytest.raises(ValueError, match="y_slab|checkpoint"):
        reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v2"), iters=6, y_slab=4,
            ckpt_dir=ck,
        )


def test_streaming_overlap_is_pure_schedule(rec, sino_store, tmp_path):
    """Prefetching and device-upload double-buffering must not change
    results (same discipline as the Fig. 8 overlap test): every cell of
    the (disk overlap) x (device upload) A/B grid is bit-identical."""
    outs = {}
    for overlap in (False, True):
        for upload in ("sync", "overlap"):
            tag = f"{overlap}-{upload}"
            outs[tag] = reconstruct_streaming(
                rec, sino_store, str(tmp_path / tag), iters=5, y_slab=4,
                overlap=overlap, device_upload=upload,
            )
    base = outs["False-sync"].volume.to_array()
    for tag, res in outs.items():
        np.testing.assert_array_equal(base, res.volume.to_array())
    # only the fully overlapped schedule hides the upload
    assert outs["True-overlap"].upload_overlapped
    assert not outs["True-sync"].upload_overlapped
    assert not outs["False-overlap"].upload_overlapped


def test_streaming_timing_split(rec, sino_store, tmp_path):
    """The per-slab load/upload/solve split is recorded for every
    solved slab, in both upload modes (ISSUE 5: BENCH_stream derives
    upload-hidden-under-solve from these fields)."""
    for upload in ("sync", "overlap"):
        res = reconstruct_streaming(
            rec, sino_store, str(tmp_path / f"t_{upload}"), iters=4,
            y_slab=4, overlap=True, device_upload=upload,
        )
        n = len(res.solved)
        assert n == 2
        assert len(res.load_s) == n
        assert len(res.upload_s) == n
        assert len(res.solve_s) == n
        assert all(t > 0 for t in res.solve_s)
        assert all(t >= 0 for t in res.load_s)
        assert all(t >= 0 for t in res.upload_s)
        # solve dominates this CPU workload: the hidden upload fits
        # under it, which is what "upload hidden under solve" means
        if upload == "overlap":
            assert res.upload_overlapped
    with pytest.raises(ValueError, match="device_upload"):
        reconstruct_streaming(
            rec, sino_store, str(tmp_path / "bad"), iters=2, y_slab=4,
            device_upload="nope",
        )


def test_staged_slab_reconstruct_matches(rec, sino8):
    """Reconstructor.stage_sino + reconstruct(StagedSlab) is the same
    computation as reconstruct(numpy), bit for bit."""
    y = sino8[:, :4]
    staged = rec.stage_sino(y)
    assert isinstance(staged, StagedSlab) and staged.n_slices == 4
    x_direct, r_direct = rec.reconstruct(y, iters=5)
    x_staged, r_staged = rec.reconstruct(staged, iters=5)
    np.testing.assert_array_equal(x_direct, x_staged)
    np.testing.assert_array_equal(r_direct, r_staged)


def test_streaming_guards(rec, sino_store, tmp_path):
    with pytest.raises(ValueError, match="exactly one"):
        reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v"), iters=2
        )
    with pytest.raises(ValueError, match="multiple"):
        reconstruct_streaming(
            rec, sino_store, str(tmp_path / "v"), iters=2, y_slab=3
        )
    bad = SlabStore.create(str(tmp_path / "bad"), 7, Y, 2)
    with pytest.raises(ValueError, match="rows"):
        reconstruct_streaming(
            rec, bad, str(tmp_path / "v"), iters=2, y_slab=2
        )
    assert os.path.isdir(sino_store.directory)


def test_slab_store_concurrent_range_reads(tmp_path):
    """Memmap-backed range reads are safe under concurrency: two threads
    reading overlapping ranges of the same store must both see exactly
    the published bytes (the serve layer streams previews off shards
    other readers may be scanning)."""
    import threading

    rng = np.random.default_rng(3)
    arr = rng.standard_normal((17, 12)).astype(np.float32)
    store = SlabStore.from_array(str(tmp_path / "c"), arr, slab=4)

    ranges = [(0, 8), (4, 12), (2, 10), (0, 12)]
    results = {}
    errors = []

    def reader(tid, j0, j1):
        try:
            acc = [store.read(j0, j1) for _ in range(20)]
            for a in acc[1:]:  # every re-read identical
                np.testing.assert_array_equal(acc[0], a)
            results[tid] = acc[0]
        except Exception as e:  # noqa: BLE001
            errors.append((tid, e))

    threads = [
        threading.Thread(target=reader, args=(i, j0, j1))
        for i, (j0, j1) in enumerate(ranges)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for i, (j0, j1) in enumerate(ranges):
        np.testing.assert_array_equal(results[i], arr[:, j0:j1])
