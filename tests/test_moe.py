"""MoE dispatch: vs an explicit per-token reference implementation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_init


def _ref_moe(p, x, cfg):
    """Slow per-token reference: same top-k, same renorm, NO capacity."""
    b, t, d = x.shape
    act = jax.nn.silu
    out = np.zeros((b, t, d), np.float32)
    probs = jax.nn.softmax(x.astype(jnp.float32) @ p["router"], axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    for bi in range(b):
        for ti in range(t):
            acc = np.zeros(d, np.float32)
            for kk in range(cfg.moe_top_k):
                e = int(top_e[bi, ti, kk])
                xx = np.asarray(x[bi, ti], np.float32)
                h = xx @ np.asarray(p["wi"][e])
                g = act(jnp.asarray(xx @ np.asarray(p["wg"][e])))
                o = (np.asarray(g) * h) @ np.asarray(p["wo"][e])
                acc += float(top_p[bi, ti, kk]) * o
            out[bi, ti] = acc
    return out


def test_moe_matches_reference_when_capacity_ample():
    cfg = get_config(
        "moonshot-v1-16b-a3b", smoke=True, moe_capacity_factor=8.0
    )
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 12, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, x, cfg=cfg)
    ref = _ref_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_degrade_gracefully():
    """Tiny capacity must still return finite outputs (dropped tokens get
    zero contribution, not garbage)."""
    cfg = get_config(
        "moonshot-v1-16b-a3b", smoke=True, moe_capacity_factor=0.05
    )
    key = jax.random.PRNGKey(1)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, _ = moe_apply(p, x, cfg=cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dropped contributions shrink the output norm vs ample capacity
    cfg2 = get_config(
        "moonshot-v1-16b-a3b", smoke=True, moe_capacity_factor=8.0
    )
    y2, _ = moe_apply(p, x, cfg=cfg2)
    assert np.linalg.norm(np.asarray(y)) <= np.linalg.norm(
        np.asarray(y2)
    ) + 1e-3


def test_moe_grad_flows():
    cfg = get_config("grok-1-314b", smoke=True)
    key = jax.random.PRNGKey(2)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32)

    def loss(p):
        y, aux = moe_apply(p, x, cfg=cfg)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(v)) for v in jax.tree.leaves(g)]
    assert all(np.isfinite(norms))
    assert sum(norms) > 0
