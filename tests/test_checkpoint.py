"""Checkpoint/restart: roundtrip, atomicity, resume, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import (
    CheckpointManager, latest_step, restore, save,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,))},
        "opt": {"m": jnp.ones((8, 8)), "count": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 10, t)
    like = jax.eval_shape(lambda: _tree())
    r = restore(str(tmp_path), 10, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_publish_no_tmp_visible(tmp_path):
    save(str(tmp_path), 3, _tree())
    entries = os.listdir(tmp_path)
    assert not any(e.endswith(".tmp") for e in entries)
    assert latest_step(str(tmp_path)) == 3


def test_gc_keeps_last_k(tmp_path):
    for s in range(6):
        save(str(tmp_path), s, _tree(), keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert len(steps) == 2 and steps[-1] == "step_000000005"


def test_manager_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2)
    state = _tree(1)
    assert not mgr.maybe_save(1, state)
    assert mgr.maybe_save(2, state)
    restored, step = mgr.restore_or_init(lambda: _tree(99))
    assert step == 2
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(state["params"]["w"]),
    )


def test_restore_or_init_fresh(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1)
    state, step = mgr.restore_or_init(lambda: _tree(5))
    assert step == 0
    assert state["opt"]["count"] == 7


def test_solver_state_roundtrip(tmp_path):
    """CG state (x, r, p, iteration) resumes mid-solve."""
    cg_state = {
        "x": jnp.ones((16, 4)), "r": jnp.full((16, 4), 0.5),
        "p": jnp.zeros((16, 4)), "iter": jnp.int32(12),
    }
    save(str(tmp_path), 12, cg_state)
    like = jax.eval_shape(lambda: cg_state)
    r = restore(str(tmp_path), 12, like)
    assert int(r["iter"]) == 12
