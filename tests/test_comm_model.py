"""CommPlan wire-volume model: launch-layer parity + sparse dedup.

Everything the launch layer reports about communication volume must be a
view over ``dist.CommPlan`` -- these tests pin the two unification
points:

  * ``launch.xct_perf.comm_volume`` returns exactly what the resolved
    plans model, per link class, for every mode (regression for the old
    hand-rolled ``direct`` branch that double-counted DCI with a 2x
    all-reduce factor on top of the pod fan-out);
  * the hierarchical sparse exchange's socket-level dedup strictly
    reduces modeled DCI bytes vs the flat ``sparse`` all-to-all, both on
    a real small plan (exact tables) and at xct-brain scale (analytic
    estimates).
"""
import math

import numpy as np
import pytest

from repro.configs.xct_datasets import DATASETS
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import (
    PartitionConfig,
    build_hier_sparse_exchange,
    build_plan,
    build_sparse_exchange,
    estimate_plan,
    exchange_volume_params,
)
from repro.dist import MODES, Topology
from repro.launch.xct_perf import comm_volume, sweep_topology


@pytest.fixture(scope="module")
def small_plan():
    geo = XCTGeometry(n=32, n_angles=24)
    a = build_system_matrix(geo)
    return build_plan(
        geo,
        PartitionConfig(n_data=4, tile=4, rows_per_block=16,
                        nnz_per_stage=16),
        a=a,
    )


def test_comm_volume_matches_commplan_all_modes(small_plan):
    """comm_volume is a pure view over CommPlan -- per-link parity."""
    topo = Topology.from_sizes(
        [("model", 2, "ici"), ("data", 2, "dci")]
    )
    fuse, cb = 4, 2
    for mode in MODES:
        got = comm_volume(small_plan, mode, fuse, cb, topo)
        want = {"ici": 0.0, "dci": 0.0}
        for op in (small_plan.proj, small_plan.back):
            dense = float(op.n_rows_pad) * fuse * cb
            cp = topo.plan(mode, **exchange_volume_params(op, topo))
            for link, b in cp.wire_bytes_by_link(dense).items():
                want[link] += b
        assert got == pytest.approx(want), mode


def test_direct_dci_not_double_counted(small_plan):
    """Regression: the old hand-rolled ``direct`` branch charged DCI a
    2x all-reduce factor on top of the pod fan-out.  In the paper's
    reduce-semantics accounting (Table IV) the flat all-reduce reduces
    the full dense partial at the global rung: DCI bytes == the dense
    partial, once, same as ``rs``."""
    topo = Topology.from_sizes(
        [("model", 2, "ici"), ("data", 2, "dci")]
    )
    fuse, cb = 4, 2
    dense_total = sum(
        float(op.n_rows_pad) * fuse * cb
        for op in (small_plan.proj, small_plan.back)
    )
    direct = comm_volume(small_plan, "direct", fuse, cb, topo)
    assert direct["dci"] == pytest.approx(dense_total)
    assert direct == pytest.approx(
        comm_volume(small_plan, "rs", fuse, cb, topo)
    )


def test_socket_dedup_strictly_reduces_dci_exact(small_plan):
    """Exact tables: hier-sparse DCI < flat sparse DCI, because the
    socket members' overlapping footprints are merged before crossing
    the slow link (and the merged band is strictly smaller than the sum
    of the members' bands)."""
    topo = Topology.from_sizes(
        [("model", 2, "ici"), ("data", 2, "dci")]
    )
    for op in (small_plan.proj, small_plan.back):
        params = exchange_volume_params(op, topo)
        dense = float(op.n_rows_pad)
        flat = topo.plan("sparse", **params).wire_bytes_by_link(dense)
        hs = topo.plan("hier-sparse", **params).wire_bytes_by_link(dense)
        assert hs["dci"] < flat["dci"]
        # ... and the model mirrors the real table capacities
        _, _, v = build_sparse_exchange(op)
        _, _, _, w, v2 = build_hier_sparse_exchange(op, 2)
        assert params["pair_slots"] == v
        assert params["merged_rows"] == 2 * w
        assert params["cross_rows"] == 2 * v2
        # dedup in rows, not just padding: the merged band is smaller
        # than the stacked member bands
        foot_sum = sum(r.size for r in op.foot_rows)
        assert params["merged_rows"] <= foot_sum


def test_socket_dedup_reduces_dci_at_brain_scale():
    """Acceptance: modeled DCI bytes of hier-sparse at xct-brain scale
    (P_d = 512 over two pods) are strictly below flat sparse."""
    ds = DATASETS["xct-brain"]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    plan = estimate_plan(
        geo,
        PartitionConfig(n_data=512, tile=32, rows_per_block=64,
                        nnz_per_stage=64),
    )
    topo = sweep_topology(512)
    assert [lv.link for lv in topo.levels] == ["ici", "ici", "dci"]
    flat = comm_volume(plan, "sparse", 16, 2, topo)
    hs = comm_volume(plan, "hier-sparse", 16, 2, topo)
    direct = comm_volume(plan, "direct", 16, 2, topo)
    assert hs["dci"] < flat["dci"]
    assert hs["dci"] < direct["dci"]


def test_hier_sparse_level_fracs_shape():
    """Per-link accounting of the new mode: the socket rung carries the
    merged band, every slower rung the cross-socket slots."""
    topo = Topology.from_sizes(
        [("model", 4, "ici"), ("data", 4, "ici"), ("pod", 2, "dci")]
    )
    cp = topo.plan(
        "hier-sparse", dense_rows=1000, merged_rows=400, cross_rows=80
    )
    assert cp.level_fracs == pytest.approx((0.4, 0.08, 0.08))
    assert [s.op for s in cp.steps] == ["reduce_scatter", "all_to_all"]
    assert cp.steps[0].axes == ("model",)
    assert cp.steps[1].axes == ("data", "pod")
    by_link = cp.wire_bytes_by_link(1000.0)
    assert by_link["ici"] == pytest.approx(400.0 + 80.0)
    assert by_link["dci"] == pytest.approx(80.0)
    # without the table capacities the volume model is NaN, never wrong
    assert math.isnan(topo.plan("hier-sparse").level_fracs[0])


def test_hier_sparse_tables_route_every_partial(small_plan):
    """Host-side replay of the three stages: scatter into the merged
    band, fast-axis reduce-scatter, slow-axis all-to-all, owner
    scatter-add -- must equal the dense reduction exactly."""
    G, n_slow = 2, 2
    for op in (small_plan.proj, small_plan.back):
        smap, send2, recv2, w, v2 = build_hier_sparse_exchange(op, G)
        P, rpd = 4, op.rows_per_dev
        rng = np.random.default_rng(0)
        bands = rng.standard_normal((P, op.flat_rows))
        dense = np.zeros(op.n_rows_pad)
        for p in range(P):
            rm = op.row_map[p].reshape(-1)
            valid = rm < op.n_rows_pad
            bands[p][~valid] = 0.0
            np.add.at(dense, rm[valid], bands[p][valid])
        out = np.zeros((P, rpd))
        for t in range(n_slow):
            merged = np.zeros(G * w + 1)
            for f in range(G):
                np.add.at(merged, smap[f * n_slow + t],
                          bands[f * n_slow + t])
            merged = merged[:-1]
            for f in range(G):
                src = f * n_slow + t
                mine = np.append(merged[f * w:(f + 1) * w], 0.0)
                for t2 in range(n_slow):
                    q = f * n_slow + t2
                    tgt = np.zeros(rpd + 1)
                    np.add.at(tgt, recv2[q, t], mine[send2[src, t2]])
                    out[q] += tgt[:rpd]
        np.testing.assert_allclose(out.reshape(-1), dense, atol=1e-12)


def test_hilbert_socket_layout_improves_dedup(small_plan):
    """ROADMAP item: socket-aware chunk linearization.  Under the default
    fast-axis-major order, a socket's members own Hilbert chunks that are
    ``n_slow`` apart on the curve; with ``PartitionConfig(socket=G)`` they
    own *consecutive* chunks, whose band footprints shadow each other --
    the measured per-socket union (what the hier-sparse merged band
    ships) must strictly shrink."""
    geo = small_plan.geo
    a = build_system_matrix(geo)
    cfg = small_plan.cfg
    aware = build_plan(
        geo,
        PartitionConfig(
            n_data=cfg.n_data, tile=cfg.tile,
            rows_per_block=cfg.rows_per_block,
            nnz_per_stage=cfg.nnz_per_stage, socket=2,
        ),
        a=a,
    )

    def union_rows(op, fast):
        p = op.inds.shape[0]
        n_slow = p // fast
        total = 0
        for t in range(n_slow):
            rows = np.concatenate(
                [op.row_map[f * n_slow + t].reshape(-1)
                 for f in range(fast)]
            )
            total += np.unique(rows[rows < op.n_rows_pad]).size
        return total

    for name in ("proj", "back"):
        legacy = union_rows(getattr(small_plan, name), 2)
        hilbert = union_rows(getattr(aware, name), 2)
        assert hilbert < legacy, (name, legacy, hilbert)


def test_q8_operator_pricing_at_brain_scale():
    """Acceptance (ISSUE 8): the q8 tier halves the operator *value*
    stream at xct-brain scale -- 1 B/nnz + the per-(block, stage) scale
    table vs f16's 2 B/nnz -- and every byte-accounting consumer sees
    it: ``hbm_bytes`` drops by the vals share (indices stay 2 B, so the
    total lands at ~0.80x) and ``spmm_traffic`` prices a strictly
    smaller operator stream / higher arithmetic intensity."""
    from repro.kernels.traffic import op_segments_per_stage, spmm_traffic

    ds = DATASETS["xct-brain"]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    plan = estimate_plan(
        geo,
        PartitionConfig(n_data=512, tile=32, rows_per_block=64,
                        nnz_per_stage=64),
    )
    op = plan.proj
    h_f16 = op.hbm_bytes(value_bytes=2)
    h_q8 = op.hbm_bytes(value_bytes=1)
    meta = op.hbm_bytes(value_bytes=0)  # indices + winmap/row_map only
    # the value stream itself halves (scale table is B*S int32s against
    # B*S*R*K packed slots: < 0.1% overhead at the 64x64 block)
    assert 0.5 <= (h_q8 - meta) / (h_f16 - meta) <= 0.501
    assert 0.79 <= h_q8 / h_f16 <= 0.81
    traffic = {}
    for vb in (2, 1):
        _, b, s, r, k = op.inds.shape
        traffic[vb] = spmm_traffic(
            b, s, r, k, op.winmap.shape[-1], 16,
            storage_bytes=2, vals_bytes=vb,
            segments_per_stage=op_segments_per_stage(op),
        )
    assert traffic[1]["operator_bytes"] < traffic[2]["operator_bytes"]
    assert traffic[1]["hbm_bytes"] < traffic[2]["hbm_bytes"]
    ai = {vb: t["flops"] / t["hbm_bytes"] for vb, t in traffic.items()}
    assert ai[1] > ai[2]


def test_q8_wire_halves_hier_sparse_dci():
    """Acceptance (ISSUE 8): int8 wire compression halves the
    hier-sparse slow hop at xct-brain scale -- each crossing row ships
    1 B instead of ``comm_bytes=2``, plus one f32 inv-scale per
    (slow-peer, fused slice) -- and ``comm_volume`` (the launch-layer
    view over ``CommPlan``) prices exactly that."""
    from repro.core.partition import hier_sparse_wire_bytes

    ds = DATASETS["xct-brain"]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    plan = estimate_plan(
        geo,
        PartitionConfig(n_data=512, tile=32, rows_per_block=64,
                        nnz_per_stage=64),
    )
    topo = sweep_topology(512)
    native = comm_volume(plan, "hier-sparse", 16, 2, topo)
    q8 = comm_volume(plan, "hier-sparse", 16, 2, topo, wire="q8")
    # the slow-axis all-to-all spans the node ICI rung and the DCI rung:
    # its payload compresses on both, the socket reduce-scatter (the
    # bulk of ICI) stays native -- so DCI halves, ICI dips slightly
    assert 0.5 < q8["dci"] / native["dci"] <= 0.51
    assert native["ici"] * 0.9 < q8["ici"] < native["ici"]
    # ... and the closed form agrees with the CommPlan pricing per op
    n_slow = math.prod(lv.size for lv in topo.levels[1:])
    want = {"native": 0.0, "q8": 0.0}
    for op in (plan.proj, plan.back):
        params = exchange_volume_params(op, topo)
        v2 = params["cross_rows"] // n_slow
        for wire in ("native", "q8"):
            want[wire] += hier_sparse_wire_bytes(
                v2, n_slow, 16, comm_bytes=2, wire=wire
            )
    assert native["dci"] == pytest.approx(want["native"])
    assert q8["dci"] == pytest.approx(want["q8"])


def test_q8_wire_rejected_off_the_hier_sparse_ladder():
    """wire="q8" compresses the hier-sparse slow-axis all-to-all; the
    dense ladders have no such hop, so the plan must refuse rather than
    silently price uncompressed wire."""
    topo = Topology.from_sizes(
        [("model", 2, "ici"), ("data", 2, "dci")]
    )
    with pytest.raises(ValueError, match="wire"):
        topo.plan("hier", wire="q8")
    with pytest.raises(ValueError, match="wire"):
        topo.plan("hier-sparse", wire="fp4")


def test_xct_analytic_fused_staging_eliminates_hbm_term(small_plan):
    """Acceptance: the dry-run cost model drops the staged-window HBM
    round trip on the fused path -- strictly less memory traffic and
    strictly higher arithmetic intensity at the paper's F=16."""
    from repro.core.recon import ReconConfig
    from repro.launch.dryrun import xct_analytic

    topo = Topology.from_sizes(
        [("model", 2, "ici"), ("data", 2, "dci")]
    )
    fused = xct_analytic(
        small_plan, ReconConfig(precision="mixed", comm_mode="hier"),
        topo, fuse=16, iters=1,
    )
    gather = xct_analytic(
        small_plan,
        ReconConfig(precision="mixed", comm_mode="hier",
                    staging="gather"),
        topo, fuse=16, iters=1,
    )
    assert fused["flops_dev"] == gather["flops_dev"]
    assert fused["hbm_dev"] < gather["hbm_dev"]
    ai_fused = fused["flops_dev"] / fused["hbm_dev"]
    ai_gather = gather["flops_dev"] / gather["hbm_dev"]
    assert ai_fused > ai_gather


def test_socket_sweep_picks_socket_aware_layout():
    """ROADMAP open item closed: the dry-run sweep comparing
    PartitionConfig(socket=1) vs socket=fast at xct-brain scale must
    pick the socket-aware layout (consecutive Hilbert chunks per socket
    shrink the hier-sparse merged band), which is what
    core.partition.default_socket now hands every driver."""
    from repro.core.partition import default_socket
    from repro.launch.dryrun import socket_sweep

    sw = socket_sweep()
    fast = sw["fast"]
    assert sw[f"socket={fast}"]["dci"] < sw["socket=1"]["dci"]
    assert sw[f"socket={fast}"]["ici"] < sw["socket=1"]["ici"]
    assert sw["winner"] == fast == default_socket(sw["p_data"], fast)


def test_sweep_coalesced_dma_issues_strictly_drop():
    """Acceptance (ISSUE 5): at xct-brain scale, the modeled DMA-issue
    count of the coalesced window staging is strictly below the
    per-row baseline in every cell of the §Perf sweep, and the
    dominant-cost memory term reflects the issue overhead
    (kernels.traffic.dma_issue_seconds)."""
    from repro.launch.xct_perf import sweep

    coal = sweep(iters=2)
    per = sweep(iters=2, dma="per_row")
    assert len(coal) == len(per) > 0
    for c, p in zip(coal, per):
        assert c["dma_issues"] < p["dma_issues"], (c["mode"], c["fuse"])
        # same bytes, fewer issues -> the memory term can only improve
        assert c["t_memory"] < p["t_memory"]


def test_xct_analytic_carries_dma_issue_term(small_plan):
    """The dry-run cost model prices window-DMA issues: coalesced
    (measured winsegs capacity) strictly below per-row, and the field
    is present for abstract consumers (lower_xct_cell rooflines)."""
    from repro.core.recon import ReconConfig
    from repro.launch.dryrun import xct_analytic

    topo = Topology.from_sizes([("model", 2, "ici"), ("data", 2, "dci")])
    coal = xct_analytic(
        small_plan, ReconConfig(precision="mixed", comm_mode="hier"),
        topo, fuse=16, iters=1,
    )
    per = xct_analytic(
        small_plan,
        ReconConfig(precision="mixed", comm_mode="hier", dma="per_row"),
        topo, fuse=16, iters=1,
    )
    assert coal["dma_issues_dev"] < per["dma_issues_dev"]
    # descriptor pricing differs (12 B/segment vs 4 B/row) but stays a
    # small fraction of the total memory term
    assert abs(coal["hbm_dev"] - per["hbm_dev"]) < 0.2 * per["hbm_dev"]
