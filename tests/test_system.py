"""End-to-end behaviour of the full system (paper-level claims).

  1. The reconstruction pipeline recovers a phantom from its simulated
     measurements across precision ladders (Table III / Fig. 13 shape).
  2. All five communication strategies agree (Sec. III-D is a schedule
     optimization, not a math change).
  3. Training the ~100M-class example arch reduces loss (deliverable b).
  4. Drivers are importable and runnable end-to-end on CPU.
"""
import numpy as np

from repro.core.recon import ReconConfig, Reconstructor


def test_full_pipeline_all_precisions(small_system, phantom32):
    _, _, plan = small_system
    x_true, y = phantom32
    rels = {}
    for prec in ("single", "mixed", "half"):
        rec = Reconstructor(
            plan,
            cfg=ReconConfig(precision=prec, comm_mode="hier", fuse=2),
        )
        x, res = rec.reconstruct(y, iters=20)
        rels[prec] = float(
            (np.linalg.norm(x - x_true, axis=0)
             / np.linalg.norm(x_true, axis=0)).mean()
        )
        assert res[-1, 0] < res[0, 0] * 0.1, prec
    # paper Fig. 13: reduced precision converges like single
    assert rels["mixed"] < rels["single"] + 0.03
    assert rels["half"] < rels["single"] + 0.05


def test_comm_modes_equivalent(small_system, phantom32):
    _, _, plan = small_system
    x_true, y = phantom32
    outs = {}
    for mode in ("direct", "rs", "hier", "sparse", "hier-sparse"):
        rec = Reconstructor(
            plan,
            cfg=ReconConfig(precision="single", comm_mode=mode, fuse=2),
        )
        x, _ = rec.reconstruct(y, iters=8)
        outs[mode] = x
    for mode in ("rs", "hier", "sparse", "hier-sparse"):
        np.testing.assert_allclose(
            outs["direct"], outs[mode], rtol=1e-4, atol=1e-5
        )


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "smollm-135m", "--smoke", "--steps", "12",
        "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
    ])
    assert losses[-1] < losses[0]
    # resume path: second run starts from the checkpoint
    losses2 = main([
        "--arch", "smollm-135m", "--smoke", "--steps", "14",
        "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
    ])
    assert len(losses2) == 4  # steps 10..13 only


def test_serve_driver_end_to_end():
    from repro.launch.lm_serve import main

    gen = main([
        "--arch", "smollm-135m", "--smoke", "--batch", "2",
        "--prompt-len", "8", "--gen", "4",
    ])
    assert gen.shape == (2, 4)
    assert (gen >= 0).all()
