"""LM training integration: loss decreases; hier grad sync == spmd."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models.lm import make_hier_train_step, make_train_step
from repro.models.transformer import init_params
from repro.opt.adam import AdamW


def test_loss_decreases_smollm_smoke():
    cfg = get_config("smollm-135m", smoke=True)
    opt = AdamW(lr=1e-2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for s in range(25):
        params, opt_state, m = step(params, opt_state, stream.batch(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_hier_grad_sync_matches_spmd_single_device():
    """On a trivial 1x1x1 mesh the hierarchical mixed-precision gradient
    sync must reproduce the plain step up to bf16 wire quantization."""
    cfg = get_config("smollm-135m", smoke=True)
    opt = AdamW(lr=1e-3, grad_clip=0.0)
    mesh = jax.make_mesh(
        (1, 1, 1), ("pod", "data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3,
    )
    params = init_params(cfg, jax.random.PRNGKey(1))
    stream = TokenStream(cfg.vocab_size, 16, 4, seed=1)
    batch = stream.batch(0)

    p1, _, m1 = jax.jit(make_train_step(cfg, opt))(
        params, opt.init(params), batch
    )
    p2, _, m2 = jax.jit(make_hier_train_step(cfg, opt, mesh))(
        params, opt.init(params), batch
    )
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    err = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l2)
    )
    assert err < 5e-3, err  # bf16 wire + adaptive normalization


def test_adamw_step_sane():
    opt = AdamW(lr=0.1, grad_clip=0.0)
    params = {"w": jnp.ones((4,))}
    st = opt.init(params)
    grads = {"w": jnp.full((4,), 2.0)}
    new_p, st = opt.update(grads, st, params)
    # first Adam step moves by ~lr in the gradient direction
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), 1.0 - 0.1, atol=1e-3
    )
