"""Blocked-ELL partitioning: exact reconstruction + exchange tables."""
import numpy as np
import pytest

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import (
    PartitionConfig, build_hier_sparse_exchange, build_plan,
    build_sparse_exchange, default_socket, estimate_hier_sparse,
    estimate_plan,
)


def _materialize(op, n_rows, n_cols):
    """Rebuild the dense matrix a device set represents (virtual rows of
    a split matrix row sum into the same global row)."""
    p_, b, s, r, k = op.inds.shape
    dense = np.zeros((n_rows, n_cols), np.float64)
    for p in range(p_):
        c0 = p * op.cols_per_dev
        for bi in range(b):
            for si in range(s):
                win = op.winmap[p, bi, si]
                for ri in range(r):
                    gr = op.row_map[p, bi, ri]
                    if gr >= n_rows:
                        continue
                    for ki in range(k):
                        v = op.vals[p, bi, si, ri, ki]
                        if v != 0.0:
                            gc = c0 + win[op.inds[p, bi, si, ri, ki]]
                            dense[gr, gc] += v
    return dense


@pytest.mark.parametrize("slot_order", ["runs", "first_seen"])
@pytest.mark.parametrize("p", [1, 3, 4])
def test_blocked_ell_reconstructs_matrix(p, slot_order):
    geo = XCTGeometry(n=16, n_angles=12)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=p, tile=4, rows_per_block=8, nnz_per_stage=8,
        slot_order=slot_order,
    )
    plan = build_plan(geo, cfg, a=a)
    ap = a[plan.row_perm][:, plan.col_perm]
    dense = _materialize(plan.proj, geo.n_rays, plan.proj.n_cols_pad)
    assert np.allclose(
        dense[:, : geo.n_vox], ap.toarray(), atol=1e-6
    )
    # transpose operator too
    dense_t = _materialize(plan.back, geo.n_vox, plan.back.n_cols_pad)
    assert np.allclose(
        dense_t[:, : geo.n_rays], ap.T.toarray(), atol=1e-6
    )


def test_sparse_exchange_tables_complete():
    """Every footprint row appears in exactly one (sender, owner) slot."""
    geo = XCTGeometry(n=24, n_angles=16)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=4, tile=4, rows_per_block=8,
                        nnz_per_stage=8),
        a=a,
    )
    for op in (plan.proj, plan.back):
        send, recv, v = build_sparse_exchange(op)
        p = send.shape[0]
        for pp in range(p):
            rows = op.foot_rows[pp]
            n_valid = int((send[pp] < op.flat_rows).sum())
            # >=: split (virtual) rows occupy one slot per fragment
            assert n_valid >= rows.size
            # every valid slot refers to a real virtual-row position
            rm = op.row_map[pp].reshape(-1)
            n_vrows = int((rm < op.n_rows_pad).sum())
            assert n_valid == n_vrows
            # receivers: recv table entries for this sender must be
            # consistent chunk-local ids
            for q in range(p):
                mask = send[pp, q] < op.flat_rows
                assert (recv[q, pp][mask] < op.rows_per_dev).all()
                assert (recv[q, pp][~mask] == op.rows_per_dev).all()


def test_nnz_conserved(small_system):
    geo, a, plan = small_system
    assert plan.proj.nnz == a.nnz
    assert plan.back.nnz == a.nnz
    # padding overhead should be bounded (Hilbert locality keeps ELL tight)
    assert plan.proj.padded_nnz < 25 * a.nnz


def test_estimate_plan_shapes_cover_reality():
    """Analytic dry-run estimates must cover the real shapes (no gross
    undersizing) for the dimensions that drive memory."""
    geo = XCTGeometry(n=64, n_angles=48)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=8, tile=8, rows_per_block=32, nnz_per_stage=32
    )
    real = build_plan(geo, cfg, a=a)
    est = estimate_plan(geo, cfg)
    for name in ("proj", "back"):
        r, e = getattr(real, name), getattr(est, name)
        # stage capacity: estimated slots per row >= real max usage
        assert e.inds.shape[2] * 1.6 >= r.inds.shape[2], name
        assert e.n_rows_pad == r.n_rows_pad
        assert e.n_cols_pad == r.n_cols_pad
        # total slot capacity within 4x of real padded allocation
        assert 0.25 < e.padded_nnz / r.padded_nnz < 6.0, name


def test_socket_layout_reconstructs_matrix():
    """socket=G relabels both vector spaces device-major (stored block p
    = Hilbert chunk sigma[p]); the blocked-ELL shards must reconstruct
    exactly the relabeled operator, and the layout maps must be the
    block permutation they claim to be."""
    from repro.core.partition import socket_chunk_layout

    geo = XCTGeometry(n=16, n_angles=12)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=4, tile=4, rows_per_block=8, nnz_per_stage=8, socket=2
    )
    plan = build_plan(geo, cfg, a=a)
    sigma = socket_chunk_layout(4, 2)
    # socket t = slots {t, 2 + t} (fast-major, n_slow = 2) owns
    # consecutive Hilbert chunks {2t, 2t + 1}
    assert sigma.tolist() == [0, 2, 1, 3]
    # layout maps are bijections on the padded spaces
    for pos, pad in (
        (plan.row_pos, plan.proj.n_rows_pad),
        (plan.col_pos, plan.proj.n_cols_pad),
    ):
        assert pos.shape == (pad,)
        assert np.array_equal(np.sort(pos), np.arange(pad))
    # shards reconstruct the relabeled matrix
    ap = a[plan.row_perm][:, plan.col_perm].tocsr()
    dense = _materialize(
        plan.proj, plan.proj.n_rows_pad, plan.proj.n_cols_pad
    )
    want = np.zeros_like(dense)
    rows = plan.row_pos[: geo.n_rays]
    cols = plan.col_pos[: geo.n_vox]
    want[np.ix_(rows, cols)] = ap.toarray()
    assert np.allclose(dense, want, atol=1e-6)


def test_socket_layout_requires_divisibility():
    from repro.core.partition import socket_chunk_layout

    with pytest.raises(ValueError):
        socket_chunk_layout(4, 3)


@pytest.mark.parametrize(
    "n,angles,p,g", [(32, 24, 4, 2), (64, 48, 8, 4)]
)
def test_estimate_hier_sparse_adjacent_calibrated(n, angles, p, g):
    """ROADMAP item: the hier-sparse estimate assumed socket members'
    footprints were independent draws, overstating W for socket-aware
    plans.  The adjacent-chunk model (union ~ one merged subdomain's
    sqrt-law footprint, constant 1.9 calibrated like estimate_plan's)
    must cover the measured W without gross oversizing."""
    geo = XCTGeometry(n=n, n_angles=angles)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=p, tile=4, rows_per_block=16, nnz_per_stage=16, socket=g
    )
    plan = build_plan(geo, cfg, a=a)
    est = estimate_plan(geo, cfg)
    n_slow = p // g
    for name in ("proj", "back"):
        real_op = getattr(plan, name)
        _, _, _, w_real, _ = build_hier_sparse_exchange(real_op, g)
        # est_socket attached by estimate_plan selects the model
        w_est, _ = estimate_hier_sparse(getattr(est, name), g, n_slow)
        assert 0.9 <= w_est / w_real <= 1.6, (name, w_est, w_real)


def test_estimate_hier_sparse_adjacent_tighter_at_scale():
    """At xct-brain scale the adjacent-chunk union is strictly below the
    independent-draw union (the overstatement the ROADMAP flagged)."""
    geo = XCTGeometry(n=11008, n_angles=4096)
    base = dict(n_data=512, tile=32, rows_per_block=64, nnz_per_stage=64)
    legacy = estimate_plan(geo, PartitionConfig(**base, socket=1))
    aware = estimate_plan(geo, PartitionConfig(**base, socket=16))
    for name in ("proj", "back"):
        w_ind, v2_ind = estimate_hier_sparse(
            getattr(legacy, name), 16, 32
        )
        w_adj, v2_adj = estimate_hier_sparse(
            getattr(aware, name), 16, 32
        )
        assert w_adj < w_ind, name
        assert v2_adj <= v2_ind, name
        # explicit override matches the inferred selection
        assert w_adj == estimate_hier_sparse(
            getattr(legacy, name), 16, 32, socket_aware=True
        )[0]


def test_default_socket_prefers_socket_aware():
    """The dry-run sweep's winner: socket=fast whenever it divides."""
    assert default_socket(512, 16) == 16
    assert default_socket(256, 16) == 16
    assert default_socket(4, 4) == 4
    assert default_socket(510, 16) == 1  # not divisible -> legacy
    assert default_socket(8, 1) == 1  # no fast level


def test_hbm_bytes_counts_resident_operator_only(small_system):
    """Regression: ``hbm_bytes`` crashed on a phantom ``block_rows``
    attribute; it must count packed nnz + int32 metadata and nothing
    staging-related (in-kernel staging has no HBM window tensor)."""
    _, _, plan = small_system
    op = plan.proj
    want = op.padded_nnz * 4 + (
        op.winmap.size + op.winsegs.size + op.segoff.size
        + op.row_map.size
    ) * 4
    assert op.hbm_bytes() == want


def test_hbm_bytes_prices_mixed_width_shard(small_system):
    """Satellite (ISSUE 8): ``value_bytes=None`` reads the vals width
    off the array itself, so a shard already packed narrow (int8 vals
    next to int16 indices) prices correctly -- including the per-(block,
    stage) int32 scale table the quantized tier carries -- instead of
    assuming vals width == vector storage width."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.precision import quantize_block_vals

    _, _, plan = small_system
    op = plan.proj
    q, _ = quantize_block_vals(jnp.asarray(op.vals), jnp.int8)
    packed = dataclasses.replace(op, vals=np.asarray(q))
    meta = (
        op.winmap.size + op.winsegs.size + op.segoff.size
        + op.row_map.size
    ) * 4
    scale_table = int(np.prod(op.inds.shape[:3])) * 4
    assert packed.hbm_bytes(value_bytes=None) == (
        op.padded_nnz * (1 + 2) + scale_table + meta
    )
    # explicit width still wins over the array dtype (the shards
    # normally hold the f32 master copy priced at the policy's width)
    assert packed.hbm_bytes(value_bytes=2) == op.hbm_bytes()
    # the master-copy f32 shard under None prices 4-byte vals, no table
    assert op.hbm_bytes(value_bytes=None) == (
        op.padded_nnz * (4 + 2) + meta
    )


# --------------------------------------------------------------------- #
# plan_key: the serve layer's cache fingerprint
# --------------------------------------------------------------------- #
def test_plan_key_deterministic_and_kwargs_order_free():
    from repro.core.partition import plan_key
    from repro.core.recon import ReconConfig

    geo = XCTGeometry(n=32, n_angles=48)
    cfg = PartitionConfig(n_data=2, tile=8)
    a = plan_key(geo, cfg, precision="mixed", comm_mode="hier")
    b = plan_key(geo, cfg, comm_mode="hier", precision="mixed")
    assert a == b  # kwargs reordering must not change the key
    assert a.startswith("xct-") and len(a) == 4 + 16
    # dataclasses fingerprint by field values, not identity
    assert plan_key(geo, cfg, recon=ReconConfig(fuse=4)) == \
        plan_key(geo, PartitionConfig(n_data=2, tile=8),
                 recon=ReconConfig(fuse=4))


def test_plan_key_equivalent_geometries_collide():
    from repro.core.partition import plan_key

    # n_det=None is an alias for n_det=n: same scan, same cold path
    assert plan_key(XCTGeometry(n=32, n_angles=48)) == \
        plan_key(XCTGeometry(n=32, n_angles=48, n_det=32))
    # dtype spellings name the same packing
    assert plan_key(XCTGeometry(32, 48),
                    PartitionConfig(value_dtype=np.float16)) == \
        plan_key(XCTGeometry(32, 48),
                 PartitionConfig(value_dtype=np.dtype("float16")))


def test_plan_key_near_misses_do_not_collide():
    from repro.core.partition import plan_key
    from repro.core.recon import ReconConfig

    geo = XCTGeometry(n=32, n_angles=48)
    base = plan_key(geo, PartitionConfig(),
                    recon=ReconConfig(precision="mixed"))
    others = [
        plan_key(XCTGeometry(n=32, n_angles=64), PartitionConfig(),
                 recon=ReconConfig(precision="mixed")),
        plan_key(XCTGeometry(n=32, n_angles=48, vox=2.0),
                 PartitionConfig(), recon=ReconConfig(precision="mixed")),
        plan_key(geo, PartitionConfig(n_data=2),
                 recon=ReconConfig(precision="mixed")),
        plan_key(geo, PartitionConfig(rows_per_block=64),
                 recon=ReconConfig(precision="mixed")),
        plan_key(geo, PartitionConfig(value_dtype=np.float32),
                 recon=ReconConfig(precision="mixed")),
        plan_key(geo, PartitionConfig(socket=2),
                 recon=ReconConfig(precision="mixed")),
        plan_key(geo, PartitionConfig(),
                 recon=ReconConfig(precision="half")),
        plan_key(geo, PartitionConfig(),
                 recon=ReconConfig(precision="mixed", comm_mode="rs")),
        plan_key(geo, PartitionConfig(),
                 recon=ReconConfig(precision="mixed", dma="per_row")),
        plan_key(geo, PartitionConfig(),
                 recon=ReconConfig(precision="mixed", fuse=4)),
    ]
    keys = [base] + others
    assert len(set(keys)) == len(keys), keys


def test_plan_key_rejects_unstable_values():
    from repro.core.partition import plan_key

    geo = XCTGeometry(n=32, n_angles=48)
    with pytest.raises(TypeError, match="cannot fingerprint"):
        plan_key(geo, PartitionConfig(), junk=object())
    # int 1 and float 1.0 must not collide (dtype-ladder style knobs)
    assert plan_key(geo, x=1) != plan_key(geo, x=1.0)


# --------------------------------------------------------------------- #
# slot reordering (ISSUE 7): the run-extension layout's DMA regression
# pin + cache-key coverage
# --------------------------------------------------------------------- #
def test_plan_key_slot_order_distinct():
    """slot_order is part of the layout, so it must be part of the
    serve layer's cache fingerprint -- a near-miss config cannot reuse
    a differently-ordered resident operator."""
    from repro.core.partition import plan_key

    geo = XCTGeometry(n=32, n_angles=48)
    assert plan_key(geo, PartitionConfig(slot_order="runs")) != \
        plan_key(geo, PartitionConfig(slot_order="first_seen"))


def test_slot_order_validated():
    geo = XCTGeometry(n=16, n_angles=12)
    with pytest.raises(ValueError, match="slot_order"):
        build_plan(geo, PartitionConfig(slot_order="alphabetical"))


def test_slot_reordering_regression_pin():
    """Acceptance pin (ISSUE 7), at the committed bench geometry
    (benchmarks/bench_spmm: n=64, n_angles=32, tile=8, R=32, K=32).

    The run-extension slot order must (a) strictly beat a fresh
    first-seen plan on both mean copy length and issue count, (b) beat
    the COMMITTED pre-reorder baseline by the issue margins the ISSUE
    demands: mean copy length >= 4x up, DMA issues >= 2x down.  The
    legacy order is also pinned to reproduce the committed baseline
    bit-for-bit -- the A/B arm stays an honest control.
    """
    from repro.kernels.ops import dma_issue_count

    # committed benchmarks/baseline/BENCH_spmm_fusing.json, pre-reorder:
    # 105176 issues over 153600 winmap entries (BUF=600) on device 0
    BASE_ISSUES, BASE_ENTRIES = 105176, 153600
    geo = XCTGeometry(n=64, n_angles=32)
    a = build_system_matrix(geo)
    stats = {}
    for so in ("runs", "first_seen"):
        plan = build_plan(
            geo,
            PartitionConfig(n_data=1, tile=8, rows_per_block=32,
                            nnz_per_stage=32, slot_order=so),
            a=a,
        )
        op = plan.proj
        issues = dma_issue_count(op.winsegs)
        stats[so] = (issues, op.winmap.size / issues)
    # (a) strict A/B
    assert stats["runs"][0] < stats["first_seen"][0]
    assert stats["runs"][1] > stats["first_seen"][1]
    # (b) margins vs the committed baseline
    assert stats["runs"][1] >= 4 * (BASE_ENTRIES / BASE_ISSUES)
    assert 2 * stats["runs"][0] <= BASE_ISSUES
    # legacy arm reproduces the committed baseline exactly
    assert stats["first_seen"][0] == BASE_ISSUES
    assert stats["first_seen"][1] == BASE_ENTRIES / BASE_ISSUES
