"""Blocked-ELL partitioning: exact reconstruction + exchange tables."""
import numpy as np
import pytest

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import (
    PartitionConfig, build_plan, build_sparse_exchange, estimate_plan,
)


def _materialize(op, n_rows, n_cols):
    """Rebuild the dense matrix a device set represents (virtual rows of
    a split matrix row sum into the same global row)."""
    p_, b, s, r, k = op.inds.shape
    dense = np.zeros((n_rows, n_cols), np.float64)
    for p in range(p_):
        c0 = p * op.cols_per_dev
        for bi in range(b):
            for si in range(s):
                win = op.winmap[p, bi, si]
                for ri in range(r):
                    gr = op.row_map[p, bi, ri]
                    if gr >= n_rows:
                        continue
                    for ki in range(k):
                        v = op.vals[p, bi, si, ri, ki]
                        if v != 0.0:
                            gc = c0 + win[op.inds[p, bi, si, ri, ki]]
                            dense[gr, gc] += v
    return dense


@pytest.mark.parametrize("p", [1, 3, 4])
def test_blocked_ell_reconstructs_matrix(p):
    geo = XCTGeometry(n=16, n_angles=12)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=p, tile=4, rows_per_block=8, nnz_per_stage=8
    )
    plan = build_plan(geo, cfg, a=a)
    ap = a[plan.row_perm][:, plan.col_perm]
    dense = _materialize(plan.proj, geo.n_rays, plan.proj.n_cols_pad)
    assert np.allclose(
        dense[:, : geo.n_vox], ap.toarray(), atol=1e-6
    )
    # transpose operator too
    dense_t = _materialize(plan.back, geo.n_vox, plan.back.n_cols_pad)
    assert np.allclose(
        dense_t[:, : geo.n_rays], ap.T.toarray(), atol=1e-6
    )


def test_sparse_exchange_tables_complete():
    """Every footprint row appears in exactly one (sender, owner) slot."""
    geo = XCTGeometry(n=24, n_angles=16)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=4, tile=4, rows_per_block=8,
                        nnz_per_stage=8),
        a=a,
    )
    for op in (plan.proj, plan.back):
        send, recv, v = build_sparse_exchange(op)
        p = send.shape[0]
        for pp in range(p):
            rows = op.foot_rows[pp]
            n_valid = int((send[pp] < op.flat_rows).sum())
            # >=: split (virtual) rows occupy one slot per fragment
            assert n_valid >= rows.size
            # every valid slot refers to a real virtual-row position
            rm = op.row_map[pp].reshape(-1)
            n_vrows = int((rm < op.n_rows_pad).sum())
            assert n_valid == n_vrows
            # receivers: recv table entries for this sender must be
            # consistent chunk-local ids
            for q in range(p):
                mask = send[pp, q] < op.flat_rows
                assert (recv[q, pp][mask] < op.rows_per_dev).all()
                assert (recv[q, pp][~mask] == op.rows_per_dev).all()


def test_nnz_conserved(small_system):
    geo, a, plan = small_system
    assert plan.proj.nnz == a.nnz
    assert plan.back.nnz == a.nnz
    # padding overhead should be bounded (Hilbert locality keeps ELL tight)
    assert plan.proj.padded_nnz < 25 * a.nnz


def test_estimate_plan_shapes_cover_reality():
    """Analytic dry-run estimates must cover the real shapes (no gross
    undersizing) for the dimensions that drive memory."""
    geo = XCTGeometry(n=64, n_angles=48)
    a = build_system_matrix(geo)
    cfg = PartitionConfig(
        n_data=8, tile=8, rows_per_block=32, nnz_per_stage=32
    )
    real = build_plan(geo, cfg, a=a)
    est = estimate_plan(geo, cfg)
    for name in ("proj", "back"):
        r, e = getattr(real, name), getattr(est, name)
        # stage capacity: estimated slots per row >= real max usage
        assert e.inds.shape[2] * 1.6 >= r.inds.shape[2], name
        assert e.n_rows_pad == r.n_rows_pad
        assert e.n_cols_pad == r.n_cols_pad
        # total slot capacity within 4x of real padded allocation
        assert 0.25 < e.padded_nnz / r.padded_nnz < 6.0, name
