"""Collective-mode equivalence on a multi-device CPU mesh (subprocess --
the device count must be set before jax initializes).

Two layers of assurance, per the dist API contract:

  * raw ladders: ``reduce_partials`` (direct | rs | hier) and
    ``hierarchical_psum`` agree with a dense ``psum`` reference, in fp32
    exactly and through an fp16 wire cast to wire tolerance;
  * system level: ``Reconstructor.project`` / ``backproject`` match the
    scipy operator under **all five** modes (sparse and hier-sparse
    included -- their footprint tables have no raw-ladder form) on the
    oracle kernel path (``kernels/ref.py``), and the five modes agree
    with each other.
"""
import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = _SRC
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_reduction_ladders_match_dense_psum():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import Topology
from repro.dist.collectives import reduce_partials, hierarchical_psum

mesh = jax.make_mesh((2, 2), ("data", "model"))
topo = Topology.from_mesh(mesh, data_axes=("model", "data"),
                          batch_axes=())
axes = topo.data_axes
PD, ROWS, F = 4, 32, 3
rng = np.random.default_rng(0)
parts = rng.standard_normal((PD, ROWS, F)).astype(np.float32)
dense = parts.sum(0)

def shmap(body):
    f = jax.jit(jax.shard_map(
        lambda x: body(x[0])[None], mesh=mesh,
        in_specs=P(axes), out_specs=P(axes), check_vma=False))
    return np.asarray(f(jnp.asarray(parts)))

for mode in ("direct", "rs", "hier"):
    out = shmap(lambda x, m=mode: reduce_partials(x, topo, mode=m))
    got = out.reshape(ROWS, F)
    err = np.abs(got - dense).max()
    assert err < 1e-5, (mode, err)
    # fp16 wire: cast each partial before the ladder (what qcast does)
    outh = shmap(lambda x, m=mode: reduce_partials(
        x.astype(jnp.float16), topo, mode=m).astype(jnp.float32))
    relh = np.abs(outh.reshape(ROWS, F) - dense).max() / (
        np.abs(dense).max())
    assert relh < 5e-3, (mode, relh)  # fp16 wire tolerance

# legacy bare-axes call path (no Topology object)
out = shmap(lambda x: reduce_partials(x, axes, mode="rs"))
assert np.abs(out.reshape(ROWS, F) - dense).max() < 1e-5

# all-reduce semantics: every mode, every device sees the dense sum
for mode in ("direct", "rs", "hier"):
    out = shmap(lambda x, m=mode: hierarchical_psum(x, topo, mode=m))
    err = np.abs(out - dense[None]).max()
    assert err < 1e-4, (mode, err)
print("OK ladders")
""")


def test_recon_modes_match_ref_oracle():
    """All five comm modes reproduce the scipy operator through the
    oracle (kernels/ref.py) apply path, and agree with each other under
    the fp16-wire mixed policy."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import Reconstructor, ReconConfig
from repro.dist import Topology

geo = XCTGeometry(n=32, n_angles=48)
A = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=4, tile=4,
                  rows_per_block=16, nnz_per_stage=16), a=A)
mesh = jax.make_mesh((2, 2), ("data", "model"))
topo = Topology.from_mesh(mesh, data_axes=("model", "data"),
                          batch_axes=())
rng = np.random.default_rng(1)
Y = 4
x = rng.random((geo.n_vox, Y)).astype(np.float32)
y = (A @ x).astype(np.float32)
ref_p, ref_b = A @ x, A.T @ y

mixed = {}
for mode in ("direct", "rs", "hier", "sparse", "hier-sparse"):
    rec = Reconstructor(plan, topology=topo,
        cfg=ReconConfig(precision="single", comm_mode=mode, fuse=2,
                        use_ref=True))
    yhat = rec.project(x)
    err = np.abs(yhat - ref_p).max() / np.abs(ref_p).max()
    assert err < 1e-4, ("project", mode, err)
    bt = rec.backproject(y)
    err = np.abs(bt - ref_b).max() / np.abs(ref_b).max()
    assert err < 1e-4, ("backproject", mode, err)
    # fp16 wire (mixed policy): modes must agree to wire tolerance
    recm = Reconstructor(plan, topology=topo,
        cfg=ReconConfig(precision="mixed", comm_mode=mode, fuse=2,
                        use_ref=True))
    mixed[mode] = recm.project(x)
    rel = np.abs(mixed[mode] - ref_p).max() / np.abs(ref_p).max()
    assert rel < 5e-3, ("mixed project", mode, rel)

base = mixed["direct"]
for mode in ("rs", "hier", "sparse", "hier-sparse"):
    rel = np.abs(mixed[mode] - base).max() / np.abs(base).max()
    assert rel < 5e-3, ("cross-mode", mode, rel)
print("OK recon modes")
""")


def test_hier_sparse_matches_dense_psum_fp32():
    """The hierarchical sparse exchange is bit-equivalent (fp32) to the
    dense-psum reduction through the Reconstructor apply path, and
    tolerance-equivalent through the fp16 wire (mixed policy)."""
    _run("""
import numpy as np, jax
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import Reconstructor, ReconConfig
from repro.dist import Topology

geo = XCTGeometry(n=32, n_angles=48)
A = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=4, tile=4,
                  rows_per_block=16, nnz_per_stage=16), a=A)
mesh = jax.make_mesh((2, 2), ("data", "model"))
topo = Topology.from_mesh(mesh, data_axes=("model", "data"),
                          batch_axes=())
rng = np.random.default_rng(7)
x = rng.random((geo.n_vox, 4)).astype(np.float32)
y = (A @ x).astype(np.float32)

def outs(mode, prec):
    rec = Reconstructor(plan, topology=topo,
        cfg=ReconConfig(precision=prec, comm_mode=mode, fuse=2,
                        use_ref=True))
    return rec.project(x), rec.backproject(y)

# fp32 wire: direct is a dense psum + slice; the two-stage exchange
# reorders only the *summation* of identical fp32 partials along the
# same row -- demand near-bit agreement
for (ph, bh), (pd, bd) in [(outs("hier-sparse", "single"),
                            outs("direct", "single"))]:
    for got, ref in ((ph, pd), (bh, bd)):
        rel = np.abs(got - ref).max() / np.abs(ref).max()
        assert rel < 2e-6, ("fp32", rel)
# fp16 wire: tolerance equivalence
(ph, bh), (pd, bd) = outs("hier-sparse", "mixed"), outs("direct", "mixed")
for got, ref in ((ph, pd), (bh, bd)):
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 5e-3, ("fp16 wire", rel)
print("OK hier-sparse vs dense psum")
""")


def test_q8_wire_and_quantized_operator_multi_device():
    """ISSUE 8: the compressed hier-sparse exchange (int8 slow-axis
    wire) and the quantized operator tier reproduce the dense-psum
    reduction on a real 2x2 mesh.  The int8 wire quantizes ~socket-
    reduced partials, so the tolerance is one int8 grid step (~1/127)
    above the fp16 wire's."""
    _run("""
import numpy as np, jax
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import Reconstructor, ReconConfig
from repro.dist import Topology

geo = XCTGeometry(n=32, n_angles=48)
A = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=4, tile=4,
                  rows_per_block=16, nnz_per_stage=16), a=A)
mesh = jax.make_mesh((2, 2), ("data", "model"))
topo = Topology.from_mesh(mesh, data_axes=("model", "data"),
                          batch_axes=())
rng = np.random.default_rng(11)
x = rng.random((geo.n_vox, 4)).astype(np.float32)
y = (A @ x).astype(np.float32)

def outs(mode, prec, wire="native", use_ref=True):
    rec = Reconstructor(plan, topology=topo,
        cfg=ReconConfig(precision=prec, comm_mode=mode, fuse=2,
                        wire=wire, use_ref=use_ref))
    return rec.project(x), rec.backproject(y)

ref_p, ref_b = outs("direct", "mixed")
# compressed wire, f16 everything else (oracle apply path isolates the
# exchange): within the int8 wire grid of the dense reduction
for got, ref, tag in zip(outs("hier-sparse", "mixed", wire="q8"),
                         (ref_p, ref_b), ("project", "backproject")):
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 2.5e-2, (tag, rel)
# quantized operator + compressed wire through the REAL kernel path:
# in-kernel dequant under shard_map composes with the wire compression
for got, ref, tag in zip(
        outs("hier-sparse", "q8", wire="q8", use_ref=False),
        (ref_p, ref_b), ("q8 project", "q8 backproject")):
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 2.5e-2, (tag, rel)
# wire="q8" demands the hier-sparse tables -- fail loudly otherwise
try:
    Reconstructor(plan, topology=topo,
        cfg=ReconConfig(precision="mixed", comm_mode="hier", wire="q8"))
except ValueError as e:
    assert "wire" in str(e)
else:
    raise AssertionError("hier + wire=q8 should be rejected")
print("OK q8 wire")
""")
