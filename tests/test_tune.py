"""repro.tune: passport persistence + autotuner + consumer pins (ISSUE 7)."""
import dataclasses
import json

import pytest

from repro.core.geometry import XCTGeometry
from repro.tune import (
    PassportVersionError,
    TuningPassport,
    autotune,
    hardware_fingerprint,
    load_passport,
    passport_path,
    resolve_passport,
    save_passport,
)

HW = {"backend": "cpu", "device_kind": "cpu", "n_devices": 1}
GEO = XCTGeometry(n=32, n_angles=48)
# small but non-trivial sweep: every axis still exercised
SPACE = {
    "block": [(16, 16), (32, 32)],
    "slab_frac": [1.0, 0.5],
    "comm_mode": ["direct", "hier"],
}


def _tune(**kw):
    kw.setdefault("p_data", 1)
    kw.setdefault("mem_budget", 256 << 20)
    kw.setdefault("n_slices", 32)
    kw.setdefault("fuse", 4)
    kw.setdefault("space", SPACE)
    kw.setdefault("hardware", HW)
    return autotune(GEO, **kw)


def _passport(**over):
    kw = dict(
        fingerprint=hardware_fingerprint(HW), hardware=HW,
        knobs={"dma": "coalesced", "slot_order": "runs", "y_slab": 16},
    )
    kw.update(over)
    return TuningPassport(**kw)


# --------------------------------------------------------------------- #
# persistence: determinism, round trip, versioning, corruption
# --------------------------------------------------------------------- #
def test_passport_bytes_deterministic_across_runs(tmp_path):
    """Two runs of the same sweep mint BYTE-identical passport files --
    no timestamps, no dict-order noise, no environment leakage."""
    p1, _ = _tune()
    p2, _ = _tune()
    assert p1 == p2
    d1, d2 = tmp_path / "a", tmp_path / "b"
    b1 = open(save_passport(p1, str(d1)), "rb").read()
    b2 = open(save_passport(p2, str(d2)), "rb").read()
    assert b1 == b2
    # canonical form: sorted keys, compact separators, one newline
    assert b1.endswith(b"\n") and b": " not in b1


def test_passport_roundtrip(tmp_path):
    p = _passport()
    path = save_passport(p, str(tmp_path))
    assert path == passport_path(str(tmp_path), p.fingerprint)
    assert load_passport(path) == p
    assert resolve_passport(str(tmp_path), p.fingerprint) == p


def test_future_schema_version_rejected(tmp_path):
    """A passport from a NEWER build raises on strict load and demotes
    to warn+None on resolve -- never silently misread."""
    p = _passport()
    path = save_passport(p, str(tmp_path))
    raw = json.loads(open(path).read())
    raw["schema_version"] = 99
    open(path, "w").write(json.dumps(raw))
    with pytest.raises(PassportVersionError, match="schema_version=99"):
        load_passport(path)
    with pytest.warns(UserWarning, match="unusable tuning passport"):
        assert resolve_passport(str(tmp_path), p.fingerprint) is None


def test_corrupt_passport_falls_back_with_warning(tmp_path):
    p = _passport()
    path = save_passport(p, str(tmp_path))
    open(path, "w").write("{definitely not json")
    with pytest.warns(UserWarning, match="unusable tuning passport"):
        assert resolve_passport(str(tmp_path), p.fingerprint) is None
    # missing file stays SILENT -- cold start is not an anomaly
    assert resolve_passport(str(tmp_path), "0" * 16) is None


def test_fingerprint_mismatch_inside_file_warns(tmp_path):
    p = _passport()
    path = save_passport(p, str(tmp_path))
    # file named for one machine, contents minted on another
    other = passport_path(str(tmp_path), "f" * 16)
    open(other, "wb").write(open(path, "rb").read())
    with pytest.warns(UserWarning, match="embedded fingerprint"):
        assert resolve_passport(str(tmp_path), "f" * 16) is None


def test_overhead_source_validated():
    for ok in ("default", "measured-interpret", "measured"):
        _passport(overhead_source=ok)
    with pytest.raises(ValueError, match="overhead_source"):
        _passport(overhead_source="guessed")


# --------------------------------------------------------------------- #
# the autotuner itself
# --------------------------------------------------------------------- #
def test_autotune_prefers_reordered_coalesced_and_beats_baseline():
    """The modeled argmin lands on the run-extension layout with
    coalesced DMA (the issue-count winners) and the recorded objective
    beats the untuned first-seen baseline on the DMA-issue term."""
    p, trials = _tune()
    assert p.knobs["slot_order"] == "runs"
    assert p.knobs["dma"] == "coalesced"
    base = p.objective["baseline"]
    assert p.objective["dma_issue_seconds"] < base["dma_issue_seconds"]
    assert p.objective["total_seconds"] <= base["total_seconds"]
    assert p.objective["dci_bytes"] <= base["dci_bytes"]
    feas = [t for t in trials if t["feasible"]]
    assert len(feas) > 1
    assert p.objective["total_seconds"] == min(
        t["total_seconds"] for t in feas
    )


def test_autotune_records_overhead_provenance():
    p, _ = _tune()
    assert p.overhead_source == "default"
    p2, _ = _tune(per_copy_overhead_s=3e-7,
                  overhead_source="measured-interpret")
    assert p2.per_copy_overhead_s == 3e-7
    assert p2.overhead_source == "measured-interpret"
    # a different overhead reprices the issue term
    assert p2.objective["dma_issue_seconds"] == pytest.approx(
        3 * p.objective["dma_issue_seconds"]
    )


def test_autotune_infeasible_budget_raises():
    with pytest.raises(ValueError, match="no feasible candidate"):
        _tune(mem_budget=1024)  # cannot hold even one granule


# --------------------------------------------------------------------- #
# consumer pins: recon / stream / serve resolve the SAME passport
# --------------------------------------------------------------------- #
def test_consumers_resolve_same_passport(tmp_path, monkeypatch):
    """ReconConfig.tuned, suggest_slab and AdmissionController must all
    act on the same passport for the same fingerprint -- one tuning
    result, one behavior, everywhere."""
    from repro.core.partition import PartitionConfig, estimate_plan
    from repro.core.recon import ReconConfig
    from repro.dist import Topology
    from repro.serve.admission import AdmissionController
    from repro.stream.scheduler import suggest_slab
    from repro.tune import passport as passport_mod

    p, _ = _tune(fuse=2)
    save_passport(p, str(tmp_path))
    # the consumers fingerprint the LIVE process; pin it to HW
    monkeypatch.setattr(
        passport_mod, "describe_hardware", lambda: HW
    )

    rcfg = ReconConfig.tuned(tune_dir=str(tmp_path))
    assert rcfg.fuse == p.knobs["fuse"]
    assert rcfg.dma == p.knobs["dma"]
    assert rcfg.comm_mode == p.knobs["comm_mode"]
    # explicit override still wins over the passport
    assert ReconConfig.tuned(tune_dir=str(tmp_path), fuse=8).fuse == 8

    topo = Topology.from_sizes([("model", 1, "ici")])
    adm = AdmissionController(256 << 20, topo, tune_dir=str(tmp_path))
    assert adm.passport == p

    plan = estimate_plan(
        GEO,
        PartitionConfig(
            n_data=1,
            rows_per_block=p.knobs["rows_per_block"],
            nnz_per_stage=p.knobs["nnz_per_stage"],
            slot_order=p.knobs["slot_order"],
        ),
    )
    sp = suggest_slab(
        plan, rcfg, topo, 256 << 20, n_slices=64, passport=p
    )
    # tuned y_slab caps the streaming slab AND the admission pricing
    assert sp.y_slab <= p.knobs["y_slab"]
    cost = adm.price(GEO, PartitionConfig(n_data=1), rcfg, n_slices=64)
    assert cost.y_slab <= p.knobs["y_slab"]


def test_tuned_config_without_passport_is_stock(tmp_path):
    from repro.core.recon import ReconConfig

    assert ReconConfig.tuned(tune_dir=str(tmp_path)) == ReconConfig()
    assert ReconConfig.tuned() == ReconConfig()


def test_calibrated_overhead_flows_into_passport():
    """The bench micro-sweep's calibrated per-copy overhead rides into
    the passport with honest provenance: CPU runs are interpret-mode
    emulation, tagged measured-interpret, and the shared traffic model
    warns that such timings must not rank dma modes."""
    import os
    import sys

    sys.path.insert(
        0, os.path.join(os.path.dirname(__file__), "..")
    )
    from benchmarks.bench_spmm import calibrate_per_copy_overhead

    with pytest.warns(RuntimeWarning, match="interpret"):
        cal = calibrate_per_copy_overhead(
            buf=32, b=2, s=2, r=8, k=8, f=2, reps=1
        )
    assert cal["overhead_source"] == "measured-interpret"
    assert cal["per_copy_overhead_s"] >= 0.0
    assert cal["strided_issues"] > cal["contig_issues"]

    p, _ = _tune(
        per_copy_overhead_s=cal["per_copy_overhead_s"],
        overhead_source=cal["overhead_source"],
    )
    assert p.per_copy_overhead_s == cal["per_copy_overhead_s"]
    assert p.overhead_source == "measured-interpret"


def test_passport_asdict_json_stable():
    """dataclasses.asdict of a passport is JSON-serializable as-is --
    the save path cannot hit a TypeError mid-publish."""
    p, _ = _tune()
    json.dumps(dataclasses.asdict(p), sort_keys=True)
