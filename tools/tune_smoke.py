"""CI smoke for the config autotuner (see .github tune-smoke).

Runs the MODELED autotune tier -- no accelerator, same closed-form
models as the dry-run -- on the paper's largest dataset (xct-brain,
11283^2 slices x 4501 angles, 512-way data parallel) and asserts the
subsystem's load-bearing behaviors end to end:

  * determinism: two runs of the same sweep mint BYTE-identical
    passport files (canonical JSON, no timestamps);
  * the argmin beats the untuned default (first-seen slots, stock
    block, whole-budget slabs) on modeled DMA-issue seconds -- the term
    slot reordering + run-length coalescing attack -- and does not
    regress the modeled wire seconds (ICI + DCI);
  * the passport round-trips through the consumer entry point
    (``resolve_passport``) and carries the knobs every consumer reads.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/tune_smoke.py
"""
from __future__ import annotations

import sys
import tempfile


def main() -> int:
    from repro.configs.xct_datasets import DATASETS
    from repro.core.geometry import XCTGeometry
    from repro.launch.xct_perf import sweep_topology
    from repro.tune import autotune, resolve_passport, save_passport

    ds = DATASETS["xct-brain"]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    hw = {"backend": "ci-model", "device_kind": "modeled", "n_devices": 1}
    kw = dict(
        p_data=ds.p_data,
        topology=sweep_topology(ds.p_data),
        # suggest_slab budgets are machine-aggregate (operator + slabs
        # across all shards): 512 devices x 64 GiB HBM
        mem_budget=(64 << 30) * ds.p_data,
        n_slices=ds.m,
        fuse=16,
        space={"block": [(32, 32), (64, 64)], "tile": [32]},
        hardware=hw,
    )
    p1, trials = autotune(geo, **kw)
    p2, _ = autotune(geo, **kw)

    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    b1 = open(save_passport(p1, d1), "rb").read()
    b2 = open(save_passport(p2, d2), "rb").read()
    assert b1 == b2, "same sweep minted different passport bytes"

    loaded = resolve_passport(d1, p1.fingerprint)
    assert loaded == p1, "consumer resolve round-trip changed the passport"
    for knob in ("rows_per_block", "nnz_per_stage", "tile", "slot_order",
                 "dma", "comm_mode", "fuse", "precision", "wire",
                 "y_slab"):
        assert knob in loaded.knobs, f"passport missing knob {knob!r}"

    tuned, base = p1.objective, p1.objective["baseline"]
    assert tuned["dma_issue_seconds"] < base["dma_issue_seconds"], (
        "tuned config does not beat the untuned default on modeled "
        f"DMA-issue seconds: {tuned['dma_issue_seconds']:.4g} vs "
        f"{base['dma_issue_seconds']:.4g}"
    )
    # no MATERIAL wire regression: the argmin may trade the two link
    # classes against each other (hier-sparse ships more DCI but less
    # ICI than the hier ladder at 2 pods, and the q8 wire halves that
    # DCI), so guard the modeled wire SECONDS, where the link speeds
    # weigh the trade; a comm-mode downgrade (direct is ~250x DCI and
    # ~2x ICI here) still trips by orders of magnitude
    tuned_wire = tuned["ici_seconds"] + tuned["dci_seconds"]
    base_wire = base["ici_seconds"] + base["dci_seconds"]
    assert tuned_wire <= 1.001 * base_wire, (
        "tuned config regresses modeled wire seconds: "
        f"{tuned_wire:.4g} vs {base_wire:.4g}"
    )
    feas = sum(t["feasible"] for t in trials)
    assert feas > 1, f"sweep degenerate: {feas} feasible candidate(s)"

    print(
        "tune-smoke OK: xct-brain modeled sweep, "
        f"{feas}/{len(trials)} feasible, argmin "
        f"slot_order={p1.knobs['slot_order']} dma={p1.knobs['dma']} "
        f"comm={p1.knobs['comm_mode']} "
        f"block=({p1.knobs['rows_per_block']},{p1.knobs['nnz_per_stage']}) "
        f"y_slab={p1.knobs['y_slab']}; dma_issue_s "
        f"{base['dma_issue_seconds']:.4g} -> "
        f"{tuned['dma_issue_seconds']:.4g}, dci_bytes "
        f"{base['dci_bytes']:.4g} -> {tuned['dci_bytes']:.4g}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
