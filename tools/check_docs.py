"""Docs checker: doctest every doc example + verify intra-repo links.

Run from the repo root (CI's docs job does):

  PYTHONPATH=src python tools/check_docs.py

Three passes:
  1. ``python -m doctest`` over every ``docs/*.md`` and ``README.md``
     (one subprocess, so a crash in an example cannot take the link
     check down with it);
  2. ``doctest.testmod`` over the modules that carry doc examples
     (``python -m doctest path.py`` cannot import package-relative
     modules, so they are imported properly here);
  3. every relative markdown link in those files must resolve to a file
     in the repo (http(s) links and pure #anchors are skipped; a
     ``#fragment`` on an existing file is accepted).
"""
from __future__ import annotations

import doctest
import importlib
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]
# dryrun first: it must set XLA_FLAGS (512 placeholder devices) before
# anything else in this process initializes jax
DOCTEST_MODULES = [
    "repro.launch.dryrun",
    "repro.launch.xct_perf",
    "repro.kernels.traffic",
    "repro.core.partition",
    "repro.tune.passport",
    "repro.serve.admission",
    "repro.serve.batching",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.drift",
    "repro.resil.inject",
    "repro.resil.retry",
    "repro.resil.circuit",
]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_doctests() -> int:
    failed = 0
    md = [str(f.relative_to(ROOT)) for f in DOC_FILES]
    r = subprocess.run(
        [sys.executable, "-m", "doctest"] + md, cwd=ROOT,
    )
    print(f"python -m doctest {' '.join(md)}: "
          f"{'ok' if r.returncode == 0 else 'FAILED'}")
    failed += r.returncode != 0
    for name in DOCTEST_MODULES:
        mod = importlib.import_module(name)
        r = doctest.testmod(mod)
        print(f"doctest {name}: {r.attempted - r.failed}/{r.attempted} ok")
        failed += r.failed
    return failed


def check_links() -> int:
    broken = 0
    for f in DOC_FILES:
        for target in _LINK_RE.findall(f.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            resolved = (f.parent / path).resolve()
            if not resolved.exists():
                print(f"BROKEN LINK in {f.relative_to(ROOT)}: {target}")
                broken += 1
    return broken


def main() -> int:
    failed = check_doctests()
    broken = check_links()
    if failed or broken:
        print(f"FAILED: {failed} doctest failures, {broken} broken links")
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
