"""CI smoke for the observability spine (see .github obs-smoke).

Runs a tiny streaming reconstruction end to end with tracing on
(``repro.launch.recon --stream --trace``), then asserts the whole obs
contract on the artifact it produced:

  * the trace file validates against the checked-in Chrome trace-event
    schema (``repro.obs.export.validate_chrome_trace``);
  * the solve / prefetch / exchange phases are all present:
    ``stream/solve`` and ``stream/load`` complete spans, plus the
    ``recon/exchange`` modeled-wire instant;
  * the prefetch worker's loads render on their OWN Perfetto lane
    (thread-aware tracing actually separated the threads);
  * span attrs round-tripped (``stream/slab`` carries its slab index);
  * the metrics registry saw the drain (``stream_slabs_total`` and the
    modeled ``comm_bytes_total{link=}`` counters are positive).

The trace JSON is left at the path given by ``--out`` (default
``TRACE_obs_smoke.json``) for the CI artifact upload.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/obs_smoke.py
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="TRACE_obs_smoke.json")
    args = ap.parse_args(argv)

    from repro.launch import recon
    from repro.obs import metrics as obs_metrics
    from repro.obs.export import validate_chrome_trace

    recon.main([
        "--n", "32", "--angles", "24", "--slices", "8", "--iters", "3",
        "--fuse", "4", "--stream", "--mem-budget", "8",
        "--trace", args.out,
    ])

    doc = validate_chrome_trace(json.load(open(args.out)))
    events = doc["traceEvents"]
    spans = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    names = {e["name"] for e in spans}

    # solve + prefetch spans, exchange instant: the three phases the
    # drift report joins
    for required in ("stream/solve", "stream/load", "stream/slab"):
        assert required in names, (required, sorted(names))
    assert any(e["name"] == "recon/exchange" for e in instants), instants
    ex = next(e for e in instants if e["name"] == "recon/exchange")
    assert ex["args"]["ici_bytes"] > 0, ex

    # thread-aware lanes: the prefetch worker's load span must sit on a
    # different tid than the main thread's solve span
    tid_of = lambda name: {e["tid"] for e in spans if e["name"] == name}
    assert tid_of("stream/load").isdisjoint(tid_of("stream/solve")), (
        "prefetch loads share a lane with the solve thread"
    )

    # attrs round-trip through export
    slab_spans = [e for e in spans if e["name"] == "stream/slab"]
    assert all("slab" in e["args"] for e in slab_spans), slab_spans

    # the metrics registry saw the drain
    m = obs_metrics.get_metrics()
    assert m.get("stream_slabs_total") >= len(slab_spans) > 0
    assert m.get("comm_bytes_total", link="ici") > 0

    print(
        f"obs-smoke OK: {len(spans)} spans / {len(instants)} instants "
        f"across {len([e for e in events if e['ph'] == 'M'])} lanes, "
        f"schema valid, trace at {args.out}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
