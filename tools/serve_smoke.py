"""CI smoke for the reconstruction service (see .github serve-smoke).

Submits three jobs to an in-process ``repro.serve.ReconServer`` -- two
sharing one geometry, one different -- and asserts the subsystem's
load-bearing behaviors end to end:

  * the same-geometry pair runs as ONE batch against ONE cold plan
    build (plan-cache counters: 2 misses total, one per distinct key --
    the pair's second job never rebuilds);
  * progressive previews: every job streams per-slab previews while its
    status is still "running", strictly before completion;
  * every job completes and its volume store is complete on disk.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/serve_smoke.py
"""
from __future__ import annotations

import sys
import tempfile

import numpy as np


def main() -> int:
    from repro.core.geometry import XCTGeometry
    from repro.core.partition import PartitionConfig
    from repro.core.recon import ReconConfig
    from repro.serve import JobSpec, ReconServer

    geo_a = XCTGeometry(n=32, n_angles=48)
    geo_b = XCTGeometry(n=32, n_angles=64)
    pcfg = PartitionConfig(
        n_data=1, tile=8, rows_per_block=16, nnz_per_stage=16
    )
    rcfg = ReconConfig(precision="single", comm_mode="rs", fuse=2)
    rng = np.random.default_rng(0)
    y_total, y_slab = 8, 4  # 2 slabs/job: previews BEFORE completion

    def spec(geo, tenant):
        sino = rng.standard_normal(
            (geo.n_rays, y_total)
        ).astype(np.float32)
        return JobSpec(
            geo=geo, sino=sino, pcfg=pcfg, rcfg=rcfg, iters=4,
            tenant=tenant, y_slab=y_slab,
        )

    events = []  # (job id, status at publish time)
    srv = ReconServer(
        2 * 2**30,
        workdir=tempfile.mkdtemp(prefix="serve_smoke_"),
        on_preview=lambda job, pv: events.append((job.id, job.status)),
    )
    a1 = srv.submit(spec(geo_a, "alice"))
    a2 = srv.submit(spec(geo_a, "bob"))
    b = srv.submit(spec(geo_b, "carol"))
    assert a1.plan_key == a2.plan_key != b.plan_key
    drained = srv.drain()
    assert drained == 3, f"drained {drained} != 3"

    for job in (a1, a2, b):
        assert job.status == "done", (job.id, job.status, job.error)
        assert job.volume.complete()
        assert len(job.previews) == y_total // y_slab

    # the same-geometry pair was batched through one cold build
    assert len(srv.batches) == 2, srv.batches
    assert srv.batches[0]["jobs"] == [a1.id, a2.id], srv.batches
    assert srv.batches[0]["cold"] and srv.batches[1]["cold"]
    st = srv.cache.stats()
    assert st["builds"] == 2, st  # one per distinct key, NOT three
    assert st["misses"] == 2 and st["hits"] == 0, st

    # previews streamed while jobs were still running
    assert len(events) == 6, events
    assert all(status == "running" for _, status in events), events
    # the pair's first slabs interleave ahead of either volume finishing
    assert [jid for jid, _ in events[:2]] == [a1.id, a2.id], events

    print(
        "serve-smoke OK: 3 jobs, 2 batches, "
        f"{st['builds']} cold builds, {len(events)} previews "
        f"(pair first-slab: {a1.telemetry.first_slab_s:.2f}s / "
        f"{a2.telemetry.first_slab_s:.2f}s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
