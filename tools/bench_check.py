"""Bench regression gate: fresh BENCH_<suite>.json vs committed baseline.

The quick benches write ``BENCH_<suite>.json`` into the working
directory (gitignored); the committed baselines live in
``benchmarks/baseline/``.  CI's bench-smoke job runs the benches, then:

  python tools/bench_check.py --baseline benchmarks/baseline --fresh .

Rows are matched by ``name``.  For every matched row, each *guarded
field* is compared and the check fails on a regression worse than the
threshold (default 25%):

  ai             higher is better; compared ABSOLUTELY.  Modeled
                 arithmetic intensity is deterministic, so any drop is
                 a real model/layout regression, not noise.
  slices_per_s   higher is better; compared after rescaling the fresh
                 suite by the MEDIAN per-row fresh/baseline ratio
                 ("machine normalization": the committed baseline was
                 measured on a different machine, so absolute
                 wall-clock would gate runner speed, not code).  A
                 single row regressing relative to its suite-mates
                 still trips the gate; a uniformly slower runner does
                 not, and a single large improvement cannot drag the
                 other rows into false regressions (median, not mean).

Rows present on only one side are reported but do not fail the check
(benches gain/lose rows as sweeps evolve); suites missing a baseline
file are skipped.  Comparing ZERO suites is itself a failure -- a
misconfigured path must not silently disable the gate.  On failure the
tool prints how to refresh the baseline intentionally.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# field -> (direction, comparison): "up" = bigger is better, "down" =
# smaller is better; "absolute" fields gate raw values, "normalized"
# fields gate the machine-normalized shape (see module docstring)
GUARDED_FIELDS = {
    "ai": ("up", "absolute"),
    "slices_per_s": ("up", "normalized"),
    # serve suite: hit_rate is deterministic (fixed six-job mix), so a
    # drop means the plan cache or fingerprint broke -- gate absolutely;
    # jobs_per_s is wall-clock throughput -- machine-normalize it
    "hit_rate": ("up", "absolute"),
    "jobs_per_s": ("up", "normalized"),
    # spmm suite, window-DMA layout quality: both deterministic plan
    # properties (run-length tables of the committed bench geometry).
    # segs_mean = mean winmap entries per issued copy (longer runs
    # coalesce better, gate upward); dma_issues = copies issued per
    # minibatch (gate DOWNWARD -- fragmentation regressions show up
    # here first, see the slot-reordering PR)
    "segs_mean": ("up", "absolute"),
    "dma_issues": ("down", "absolute"),
    # quantized tier (ISSUE 8): both deterministic byte counts.
    # hbm_bytes = measured resident operator footprint at the row's
    # vals width (the q8 rows halve the value stream); comm_bytes =
    # per-device wire bytes of the comm_volumes row (the q8 wire rows
    # halve the slow hop).  Gate DOWNWARD so the quantization wins
    # cannot silently regress.
    "hbm_bytes": ("down", "absolute"),
    "comm_bytes": ("down", "absolute"),
}

UPDATE_HINT = """\
If this regression is intentional (model change, re-baselined bench),
refresh the committed baseline and commit it:

  PYTHONPATH=src python -m benchmarks.run --quick \\
      --only spmm,comms,stream,serve
  cp BENCH_*.json benchmarks/baseline/
  git add benchmarks/baseline
"""


def _load(path: pathlib.Path) -> dict:
    """``{row name: row dict}`` from one BENCH_<suite>.json file."""
    rows = json.loads(path.read_text())
    return {r["name"]: r for r in rows}


def _suite_scale(baseline: dict, fresh: dict, field: str) -> float:
    """Median per-row fresh/baseline ratio of ``field`` over matched
    rows -- the machine-speed factor to divide out.  Median, so one
    outlier row (a genuine big win or loss) cannot skew the scale and
    flag the unchanged rows."""
    ratios = sorted(
        float(fresh[n][field]) / float(baseline[n][field])
        for n in baseline
        if n in fresh and field in baseline[n] and field in fresh[n]
        and float(baseline[n][field]) > 0
    )
    if not ratios:
        return 1.0
    mid = len(ratios) // 2
    if len(ratios) % 2:
        return ratios[mid]
    return 0.5 * (ratios[mid - 1] + ratios[mid])


def compare(
    baseline: dict, fresh: dict, threshold: float
) -> tuple[list, list]:
    """Returns ``(failures, notes)`` comparing matched rows' guarded
    fields; a failure is a > ``threshold`` relative regression."""
    failures, notes = [], []
    scales = {
        field: (
            _suite_scale(baseline, fresh, field)
            if kind == "normalized" else 1.0
        )
        for field, (_, kind) in GUARDED_FIELDS.items()
    }
    for name in sorted(set(baseline) | set(fresh)):
        if name not in fresh:
            notes.append(f"row only in baseline (dropped?): {name}")
            continue
        if name not in baseline:
            notes.append(f"new row (no baseline): {name}")
            continue
        b, f = baseline[name], fresh[name]
        for field, (direction, kind) in GUARDED_FIELDS.items():
            if field not in b or field not in f:
                continue
            bv = float(b[field])
            fv = float(f[field]) / max(scales[field], 1e-12)
            if bv <= 0:
                continue
            rel = (fv - bv) / bv
            regressed = (
                rel < -threshold if direction == "up"
                else rel > threshold
            )
            if regressed:
                norm = (
                    f" (machine-normalized /{scales[field]:.3f})"
                    if kind == "normalized" else ""
                )
                failures.append(
                    f"{name}: {field} regressed {100 * abs(rel):.1f}% "
                    f"({bv:g} -> {fv:g}{norm})"
                )
    return failures, notes


def _span_totals(path: pathlib.Path) -> dict:
    """``{span name: total seconds}`` from a TRACE_<suite>.json file
    (Chrome trace-event JSON as written by ``repro.obs.export``)."""
    doc = json.loads(path.read_text())
    out: dict = {}
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "X":
            out[e["name"]] = out.get(e["name"], 0.0) + e["dur"] / 1e6
    return out


def span_diff(base_trace: pathlib.Path, fresh_trace: pathlib.Path) -> list:
    """Per-span-name total-duration comparison lines, largest relative
    change first -- printed next to a gated regression so the failure
    comes with its phase breakdown (which rung actually slowed down)
    instead of a bare number."""
    b, f = _span_totals(base_trace), _span_totals(fresh_trace)
    lines = []
    for name in sorted(set(b) | set(f)):
        bv, fv = b.get(name), f.get(name)
        if bv is None:
            lines.append((float("inf"), f"{name}: (new) {fv:.4f}s"))
        elif fv is None:
            lines.append((float("inf"), f"{name}: {bv:.4f}s -> (gone)"))
        elif bv > 0:
            rel = (fv - bv) / bv
            lines.append(
                (abs(rel),
                 f"{name}: {bv:.4f}s -> {fv:.4f}s ({rel:+.1%})")
            )
    lines.sort(key=lambda p: -p[0])
    return [ln for _, ln in lines]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--baseline", required=True,
        help="directory holding the committed BENCH_*.json files",
    )
    ap.add_argument(
        "--fresh", default=".",
        help="directory holding the freshly generated BENCH_*.json",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.25,
        help="relative regression that fails the check (default 0.25)",
    )
    args = ap.parse_args(argv)
    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)

    all_failures: list = []
    checked = 0
    for fresh_file in sorted(fresh_dir.glob("BENCH_*.json")):
        base_file = base_dir / fresh_file.name
        if not base_file.exists():
            print(f"SKIP {fresh_file.name}: no committed baseline")
            continue
        failures, notes = compare(
            _load(base_file), _load(fresh_file), args.threshold
        )
        for n in notes:
            print(f"  note [{fresh_file.name}] {n}")
        for f in failures:
            print(f"  FAIL [{fresh_file.name}] {f}")
        if failures:
            # a paired span trace (benchmarks.run --trace) turns the
            # bare regression into a phase breakdown
            trace_name = fresh_file.name.replace("BENCH_", "TRACE_")
            bt, ft = base_dir / trace_name, fresh_dir / trace_name
            if bt.exists() and ft.exists():
                print(f"  span breakdown [{trace_name}]:")
                for line in span_diff(bt, ft):
                    print(f"    {line}")
        all_failures += failures
        checked += 1
        print(
            f"{fresh_file.name}: "
            f"{'FAIL' if failures else 'ok'} "
            f"({len(failures)} regression(s))"
        )
    if checked == 0:
        # a gate that compares nothing is a broken gate, not a pass
        print(
            "bench_check FAILED: no suites compared -- check the "
            "--baseline/--fresh paths (fresh BENCH_*.json present? "
            "baselines committed under benchmarks/baseline/?)"
        )
        return 1
    if all_failures:
        print(
            f"\nbench_check FAILED: {len(all_failures)} regression(s) "
            f"worse than {100 * args.threshold:.0f}%\n"
        )
        print(UPDATE_HINT)
        return 1
    print("bench_check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
