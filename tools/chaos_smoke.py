"""CI smoke for the resilience layer (see .github chaos-smoke).

Drives a tiny streaming reconstruction through three seeded chaos
scenarios and asserts the whole fault-tolerance contract end to end
(the same pins as ``tests/test_resil.py``, but as a single artifact-
producing gate):

  1. **clean** -- no plan active: the baseline volume and the clean-path
     throughput;
  2. **transient** -- one injected disk read error, one corrupt shard,
     one non-finite solve, all healing on retry: the drain must finish
     COMPLETE, bit-identical to the clean run, with
     ``retries_total > 0`` and exactly the three planned faults fired;
  3. **quarantine** -- a persistent read error on one shard: exactly
     that slab lands in ``StreamResult.failed_slabs`` (and
     ``slabs_quarantined_total``), every other slab still matches the
     clean run, and a resume with the fault gone completes the volume.

Finally the clean-path perf guard: the injection sites are compiled
into the hot loops, so a drain under an *empty* activated plan (every
site consulted, nothing fires) must stay within 2x of the clean drain
-- and the inactive fast path (one attribute load + None check) must
sustain millions of consults per second.  The committed
``benchmarks/baseline`` stream numbers remain the authoritative
regression gate; this is the smoke-level canary.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/chaos_smoke.py
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args(argv)

    from repro.core.geometry import XCTGeometry, build_system_matrix
    from repro.core.partition import PartitionConfig, build_plan
    from repro.core.recon import ReconConfig, Reconstructor
    from repro.obs import metrics as obs_metrics
    from repro.resil import FaultPlan, RetryPolicy, inject
    from repro.stream import (
        SlabStore, reconstruct_streaming, simulate_to_store,
    )

    work = args.workdir or tempfile.mkdtemp(prefix="xct_chaos_")
    slices = 8
    geo = XCTGeometry(n=32, n_angles=24)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=1, tile=4, rows_per_block=16,
                        nnz_per_stage=16),
        a=a,
    )
    rec = Reconstructor(
        plan, cfg=ReconConfig(precision="single", comm_mode="rs", fuse=4)
    )
    sino = SlabStore.create(
        os.path.join(work, "sino"), geo.n_rays, slices, 4
    )
    simulate_to_store(a, geo.n, sino, noise=0.01, seed=0)
    retry = RetryPolicy(max_attempts=3, base_delay_s=0.0, jitter=0.0)

    def drain(tag, **kw):
        t0 = time.perf_counter()
        res = reconstruct_streaming(
            rec, sino, os.path.join(work, tag), iters=3, y_slab=4,
            retry=retry, **kw,
        )
        return res, time.perf_counter() - t0

    # 1. clean baseline ------------------------------------------------ #
    clean, t_clean = drain("clean")
    assert clean.complete and clean.failed_slabs == [], clean
    base = clean.volume.to_array()

    # 2. transient faults heal bit-exactly ----------------------------- #
    m = obs_metrics.set_metrics(obs_metrics.Metrics())
    fp = (
        FaultPlan(seed=7)
        .add("store/read", "io_error", key=0, attempts=(0,))
        .add("store/read", "corrupt", key=4, attempts=(0,))
        .add("recon/solve", "nonfinite", key=1, attempts=(0,))
    )
    with inject.activate(fp) as h:
        chaos, _ = drain("chaos")
    mm = obs_metrics.get_metrics()
    assert chaos.complete and chaos.failed_slabs == [], chaos
    assert chaos.retries >= 3, chaos.retries
    assert sorted(f[3] for f in h.fired) == [
        "corrupt", "io_error", "nonfinite",
    ], h.fired
    assert mm.get("retries_total", site="stream/load") >= 1
    assert mm.get("retries_total", site="stream/solve") >= 1
    assert mm.get(
        "faults_injected_total", site="store/read", kind="io_error"
    ) == 1
    np.testing.assert_array_equal(chaos.volume.to_array(), base)
    np.testing.assert_array_equal(chaos.resnorms, clean.resnorms)

    # 3. exhausted retries quarantine exactly the poison slab ---------- #
    obs_metrics.set_metrics(obs_metrics.Metrics())
    fp2 = FaultPlan(seed=11).add(
        "store/read", "io_error", key=4, attempts=None
    )
    ck = os.path.join(work, "ck")
    with inject.activate(fp2):
        part, _ = drain("poison", ckpt_dir=ck)
    mm = obs_metrics.get_metrics()
    assert part.failed_slabs == [4] and not part.complete, part
    assert mm.get("slabs_quarantined_total") == 1
    for j0, j1 in clean.volume.slabs():
        if j0 != 4:
            np.testing.assert_array_equal(
                part.volume.read(j0, j1), base[:, j0:j1]
            )
    rest = reconstruct_streaming(  # fault gone: resume heals the hole
        rec, sino, os.path.join(work, "poison"), iters=3, y_slab=4,
        retry=retry, ckpt_dir=ck,
    )
    assert rest.complete and rest.solved == [4], rest
    np.testing.assert_array_equal(rest.volume.to_array(), base)
    obs_metrics.set_metrics(m)

    # 4. clean-path guard: sites cost ~nothing ------------------------- #
    n = 1_000_000
    t0 = time.perf_counter()
    for _ in range(n):
        inject.fire("stream/load", key=0)
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 2e-6, f"inactive fire() costs {per_call:.2e}s"
    empty, t_empty = drain("empty")  # all sites consulted, none fire
    with inject.activate(FaultPlan(seed=0)):
        noop, t_noop = drain("noop")
    np.testing.assert_array_equal(noop.volume.to_array(), base)
    assert t_noop < max(2.0 * max(t_clean, t_empty), t_clean + 2.0), (
        f"empty-plan drain {t_noop:.2f}s vs clean {t_clean:.2f}s"
    )

    print(
        f"chaos-smoke OK: transient heal bit-exact "
        f"({chaos.retries} retries), quarantine -> resume bit-exact, "
        f"inactive site {per_call * 1e9:.0f} ns/call, "
        f"clean {slices / t_clean:.1f} slices/s"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
