"""3D phantom generation + measurement simulation for XCT.

Shepp-Logan-style ellipse phantoms varying smoothly along the slice axis,
plus a measurement simulator (forward projection + optional Poisson-ish
noise) so examples/benchmarks reconstruct from realistic sinograms the
same way the paper reconstructs its four beamline datasets.
"""
from __future__ import annotations

import numpy as np

__all__ = ["phantom_slices", "simulate_measurements"]

# (intensity, x0, y0, a, b, theta) -- loosely Shepp-Logan
_ELLIPSES = [
    (1.0, 0.0, 0.0, 0.69, 0.92, 0.0),
    (-0.8, 0.0, -0.0184, 0.6624, 0.874, 0.0),
    (-0.2, 0.22, 0.0, 0.11, 0.31, -18.0),
    (-0.2, -0.22, 0.0, 0.16, 0.41, 18.0),
    (0.1, 0.0, 0.35, 0.21, 0.25, 0.0),
    (0.1, 0.0, 0.1, 0.046, 0.046, 0.0),
    (0.1, -0.08, -0.605, 0.046, 0.023, 0.0),
    (0.1, 0.06, -0.605, 0.023, 0.046, 0.0),
]


def phantom_slices(n: int, n_slices: int, seed: int = 0) -> np.ndarray:
    """Returns [n*n, n_slices] float32; slices morph along the axis."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:n, 0:n]
    x = (xx - (n - 1) / 2) / (n / 2)
    y = (yy - (n - 1) / 2) / (n / 2)
    out = np.zeros((n_slices, n, n), np.float32)
    drift = rng.normal(0, 0.02, size=(len(_ELLIPSES), 2))
    for s in range(n_slices):
        z = (s + 0.5) / n_slices - 0.5  # [-0.5, 0.5]
        img = np.zeros((n, n), np.float32)
        for i, (a0, x0, y0, ea, eb, th) in enumerate(_ELLIPSES):
            # ellipses shrink away from the equatorial plane (3D-ish)
            shrink = np.sqrt(max(1e-3, 1.0 - (2 * z) ** 2))
            cx = x0 + drift[i, 0] * z * 4
            cy = y0 + drift[i, 1] * z * 4
            c, si = np.cos(np.radians(th)), np.sin(np.radians(th))
            xr = (x - cx) * c + (y - cy) * si
            yr = -(x - cx) * si + (y - cy) * c
            img += a0 * (
                (xr / (ea * shrink)) ** 2 + (yr / (eb * shrink)) ** 2
                <= 1.0
            )
        out[s] = np.clip(img, 0, None)
    return out.reshape(n_slices, n * n).T.astype(np.float32).copy()


def simulate_measurements(
    a_csr, x: np.ndarray, noise: float = 0.0, seed: int = 0
) -> np.ndarray:
    """Sinograms ``y = A x (+ noise)``; x [n_vox, Y] -> y [n_rays, Y]."""
    y = (a_csr @ x).astype(np.float32)
    if noise > 0:
        rng = np.random.default_rng(seed)
        scale = np.abs(y).max() or 1.0
        y = y + rng.normal(0.0, noise * scale, size=y.shape).astype(
            np.float32
        )
    return y
