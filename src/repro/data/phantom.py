"""3D phantom generation + measurement simulation for XCT.

Shepp-Logan-style ellipse phantoms varying smoothly along the slice axis,
plus a measurement simulator (forward projection + optional Poisson-ish
noise) so examples/benchmarks reconstruct from realistic sinograms the
same way the paper reconstructs its four beamline datasets.
"""
from __future__ import annotations

import numpy as np

__all__ = ["phantom_slices", "simulate_measurements"]

# (intensity, x0, y0, a, b, theta) -- loosely Shepp-Logan
_ELLIPSES = [
    (1.0, 0.0, 0.0, 0.69, 0.92, 0.0),
    (-0.8, 0.0, -0.0184, 0.6624, 0.874, 0.0),
    (-0.2, 0.22, 0.0, 0.11, 0.31, -18.0),
    (-0.2, -0.22, 0.0, 0.16, 0.41, 18.0),
    (0.1, 0.0, 0.35, 0.21, 0.25, 0.0),
    (0.1, 0.0, 0.1, 0.046, 0.046, 0.0),
    (0.1, -0.08, -0.605, 0.046, 0.023, 0.0),
    (0.1, 0.06, -0.605, 0.023, 0.046, 0.0),
]


def phantom_slices(
    n: int,
    n_slices: int,
    seed: int = 0,
    *,
    start: int = 0,
    stop: int | None = None,
) -> np.ndarray:
    """Returns [n*n, stop-start] float32; slices morph along the axis.

    ``start``/``stop`` select a slab of the *global* ``n_slices``-slice
    volume: the ellipse drift depends only on ``seed`` and each slice
    only on its global index, so generating a volume slab-by-slab is
    bit-identical to one call over the full range (what the streaming
    fixture writer ``stream.store.simulate_to_store`` relies on).
    """
    stop = n_slices if stop is None else stop
    if not 0 <= start <= stop <= n_slices:
        raise ValueError((start, stop, n_slices))
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:n, 0:n]
    x = (xx - (n - 1) / 2) / (n / 2)
    y = (yy - (n - 1) / 2) / (n / 2)
    out = np.zeros((stop - start, n, n), np.float32)
    drift = rng.normal(0, 0.02, size=(len(_ELLIPSES), 2))
    for s in range(start, stop):
        z = (s + 0.5) / n_slices - 0.5  # [-0.5, 0.5]
        img = np.zeros((n, n), np.float32)
        for i, (a0, x0, y0, ea, eb, th) in enumerate(_ELLIPSES):
            # ellipses shrink away from the equatorial plane (3D-ish)
            shrink = np.sqrt(max(1e-3, 1.0 - (2 * z) ** 2))
            cx = x0 + drift[i, 0] * z * 4
            cy = y0 + drift[i, 1] * z * 4
            c, si = np.cos(np.radians(th)), np.sin(np.radians(th))
            xr = (x - cx) * c + (y - cy) * si
            yr = -(x - cx) * si + (y - cy) * c
            img += a0 * (
                (xr / (ea * shrink)) ** 2 + (yr / (eb * shrink)) ** 2
                <= 1.0
            )
        out[s - start] = np.clip(img, 0, None)
    return out.reshape(stop - start, n * n).T.astype(np.float32).copy()


def simulate_measurements(
    a_csr,
    x: np.ndarray,
    noise: float = 0.0,
    seed: int = 0,
    *,
    chunk: int = 64,
    first_slice: int = 0,
) -> np.ndarray:
    """Sinograms ``y = A x (+ noise)``; x [n_vox, Y] -> y [n_rays, Y].

    The forward projection is chunked over slices (``chunk`` columns per
    ``A @ x`` product) so a large ``Y`` never materializes scipy's
    intermediate on top of the output: peak extra memory is one
    ``[n_rays, chunk]`` block.  The noise stream is *per slice*, seeded
    by ``(seed, global slice index)`` with the noise scale taken per
    slice -- so the result is independent of ``chunk`` and, via
    ``first_slice``, of how the volume is split into slabs
    (slab-by-slab simulation == one-shot simulation, bit for bit).
    """
    n_rays, y_slices = a_csr.shape[0], x.shape[1]
    y = np.empty((n_rays, y_slices), np.float32)
    step = max(1, int(chunk))
    for j0 in range(0, y_slices, step):
        j1 = min(j0 + step, y_slices)
        y[:, j0:j1] = (a_csr @ x[:, j0:j1]).astype(np.float32)
        if noise > 0:
            for j in range(j0, j1):
                rng = np.random.default_rng([seed, first_slice + j])
                scale = np.abs(y[:, j]).max() or 1.0
                y[:, j] += rng.normal(
                    0.0, noise * scale, size=n_rays
                ).astype(np.float32)
    return y
