"""Deterministic synthetic token pipeline.

Every batch is a pure function of ``(seed, step, shard)``: any host can
regenerate any shard at any time, which is the property the fault-tolerance
layer relies on (a reassigned or restarted worker never loses data, and
stragglers can be re-balanced without coordination -- see dist/fault.py).

The stream is not uniform noise: tokens follow a Zipf-like marginal with
Markov structure, so cross-entropy actually *decreases* under training
(needed by the end-to-end example and integration tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1

    def shard_batch(self, step: int, shard: int) -> dict:
        """[batch/n_shards, seq] tokens for (step, shard) -- pure function."""
        assert self.global_batch % self.n_shards == 0
        b = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence(
                [self.seed, int(step), int(shard), 0xC7]
            )
        )
        v = self.vocab_size
        # Zipf marginal over a small "frequent" head + Markov chain: the
        # next token is (prev * 31 + noise) % head with prob q, else random.
        head = max(8, v // 16)
        toks = np.empty((b, self.seq_len), np.int64)
        toks[:, 0] = rng.zipf(1.5, size=b) % head
        noise = rng.random((b, self.seq_len))
        rand = rng.integers(0, v, size=(b, self.seq_len))
        for t in range(1, self.seq_len):
            follow = (toks[:, t - 1] * 31 + 7) % head
            toks[:, t] = np.where(noise[:, t] < 0.75, follow, rand[:, t])
        return {
            "inputs": toks.astype(np.int32),
            "labels": toks.astype(np.int32),
        }

    def batch(self, step: int) -> dict:
        shards = [
            self.shard_batch(step, s) for s in range(self.n_shards)
        ]
        return {
            k: np.concatenate([s[k] for s in shards], axis=0)
            for k in shards[0]
        }
