"""Deterministic data sources: token streams and XCT phantoms."""
