"""Topology-aware hierarchical communication (paper Sec. III-B).

The paper reduces partial sinograms/tomograms with a *hierarchy* of
communicators matched to the machine's links: first among GPUs that share
a socket, then across sockets within a node, then across nodes -- each
rung a faster, smaller reduction whose output is all the slower rung must
carry.  On TPU meshes the rungs map onto mesh axes:

  paper level   mesh axis   link class        production role
  -----------   ---------   ---------------   -------------------------
  socket        "model"     minor ICI (fast)  in-slice data parallelism
  node          "data"      major ICI         data parallelism
  global        "pod"       DCI (slow)        outermost / multi-pod

(see ``launch.mesh.mesh_axis_classes``).  :class:`Topology` declares that
ladder once; :class:`CommPlan` resolves a reduction mode
(``direct | rs | hier | sparse | hier-sparse``) against it into a
schedule of per-level collectives plus a per-level wire-volume model.  The runtime entry points
(:func:`reduce_partials`, :func:`sparse_exchange`,
:func:`hierarchical_psum`) and the volume accounting in benchmarks are
all views over the same plan.

Submodules:
  topology     Topology / CommPlan / Level (the ladder engine)
  collectives  shard_map-level reductions and the sparse exchange
  sharding     parameter / batch / cache PartitionSpecs
  fault        stragglers, rebalancing, remesh, checkpoint cadence
"""
from .collectives import (  # noqa: F401
    hierarchical_psum,
    reduce_partials,
    sparse_exchange,
)
from .topology import (  # noqa: F401
    CommPlan,
    CommStep,
    Level,
    LINK_CLASSES,
    MODES,
    Topology,
)

__all__ = [
    "Topology",
    "CommPlan",
    "CommStep",
    "Level",
    "LINK_CLASSES",
    "MODES",
    "reduce_partials",
    "sparse_exchange",
    "hierarchical_psum",
]
