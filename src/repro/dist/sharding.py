"""Parameter / batch / cache PartitionSpecs for the LM substrate.

One rule, applied uniformly (megatron-style tensor parallelism): every
matrix-like parameter shards its largest eligible dimension over the
``model`` mesh axis; vectors, scalars and indivisible shapes replicate.
Scan-stacked parameter leaves (leading ``n_per`` period dimension, see
``models.transformer.init_params``) never shard the stacking dimension.

Batch-like trees shard their leading (batch) dimension over the data-
parallel axes.  All functions return *specs* (pytrees of PartitionSpec);
``shardings`` binds them to a mesh as NamedShardings.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "shardings"]


def _ndim_shape(leaf):
    shape = tuple(getattr(leaf, "shape", ()))
    return len(shape), shape


def _model_spec(leaf, model_axis: str, size: int):
    ndim, shape = _ndim_shape(leaf)
    if ndim < 2 or size <= 1:
        return P()
    # Candidate dims: all but a leading stack dim when ndim >= 3
    # (scan-stacked layers / MoE expert stacks keep dim 0 whole).
    start = 1 if ndim >= 3 else 0
    best, best_size = None, 0
    for i in range(start, ndim):
        if shape[i] % size == 0 and shape[i] >= best_size:
            best, best_size = i, shape[i]  # ties -> later dim wins
    if best is None:
        return P()
    return P(*(model_axis if i == best else None for i in range(ndim)))


def param_specs(params, mesh, model_axis: str = "model"):
    """PartitionSpec tree for a parameter pytree (tensor parallelism)."""
    size = dict(mesh.shape).get(model_axis, 1)
    return jax.tree.map(
        lambda leaf: _model_spec(leaf, model_axis, size), params
    )


def batch_specs(batch, mesh, dp_axes=("pod", "data")):
    """Shard each leaf's leading dimension over the data-parallel axes
    (when divisible); everything else replicates."""
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]

    def spec(leaf):
        ndim, shape = _ndim_shape(leaf)
        if not dp or ndim == 0 or shape[0] % ndp:
            return P()
        return P(*((dp,) + (None,) * (ndim - 1)))

    return jax.tree.map(spec, batch)


def cache_specs(cache, cfg, mesh, dp_axes=("pod", "data")):
    """PartitionSpec tree for a decode cache (``transformer.init_cache``).

    Cache leaves are batch-major -- ``[B, ...]`` under ``rem``, stacked
    ``[n_per, B, ...]`` under ``scan`` -- so the batch dimension position
    depends on the subtree; leaves too small to split replicate.
    """
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]

    def spec(path, leaf):
        ndim, shape = _ndim_shape(leaf)
        stacked = bool(path) and getattr(path[0], "key", None) == "scan"
        b_dim = 1 if stacked else 0
        if not dp or ndim <= b_dim or shape[b_dim] % ndp:
            return P()
        parts = [None] * ndim
        parts[b_dim] = dp
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, cache)


def shardings(specs, mesh):
    """Bind a spec tree to a mesh: pytree of NamedShardings."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
