"""Runtime collectives: thin views over the :class:`CommPlan` ladder.

All three entry points must be called *inside* ``shard_map`` with the
topology's data axes manual.  They accept either a :class:`Topology`
(preferred -- carries link classes and static sizes) or a bare tuple of
mesh axis names, fast -> slow (legacy call sites), which is promoted to a
mesh-less topology resolved for schedule only.

  reduce_partials    dense partial [rows_pad, F] -> owned chunk
                     (direct | rs | hier)
  sparse_exchange    footprint-compressed banded exchange (sparse)
  hierarchical_psum  all-reduce semantics for gradient sync
                     (direct | rs | hier)

Half-precision wire formats are the caller's choice: cast with
``core.precision.qcast`` (adaptive normalization) before the exchange and
multiply the inverse scale back after -- see ``core/recon.py`` and
``models/lm.py`` for the canonical pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .topology import CommPlan, LINK_CLASSES, Topology

__all__ = ["reduce_partials", "sparse_exchange", "hierarchical_psum"]


def _as_topology(topo_or_axes) -> Topology:
    if isinstance(topo_or_axes, Topology):
        return topo_or_axes
    if isinstance(topo_or_axes, str):
        topo_or_axes = (topo_or_axes,)
    return Topology.from_sizes(
        [(a, _axis_size(a), LINK_CLASSES.get(a, "ici"))
         for a in topo_or_axes]
    )


def _axis_size(axis: str) -> int:
    """Static size of a named axis, resolvable inside a shard_map trace."""
    return int(jax.lax.psum(1, axis))


def reduce_partials(x, topo_or_axes, *, mode: str = "hier"):
    """Reduce per-device dense partials to each device's owned chunk.

    Args:
      x: [rows_pad, F] dense partial (rows_pad divisible by the group
        size; the scatter-add in ``core/recon.py`` produces exactly this).
      topo_or_axes: Topology, or mesh axis names fast -> slow.
      mode: direct | rs | hier.

    Returns:
      [rows_pad / n_data, F] owned chunk, ordered by
      ``jax.lax.axis_index(axes)``.
    """
    topo = _as_topology(topo_or_axes)
    return topo.plan(mode).reduce_partials(x)


def hierarchical_psum(x, topo_or_axes, *, mode: str = "hier"):
    """All-reduce with the plan's schedule (gradient sync).

    ``hier`` realizes the paper's ladder -- reduce-scatter the fast
    levels, all-reduce the slowest at reduced volume, all-gather back --
    on backends whose partitioner supports scatter collectives under
    partially-manual shard_map (TPU); elsewhere it degrades to one
    all-reduce per level (identical values).
    """
    topo = _as_topology(topo_or_axes)
    return topo.plan(mode).psum(x)


def sparse_exchange(band, send_idx, recv_idx, topo_or_axes, rows_out: int):
    """Footprint-compressed banded exchange (plan mode "sparse").

    Each device's SpMM emits partials only for the virtual-row band its
    shard touches (an O(1/sqrt(P)) subset of global rows -- paper Fig.
    6-7).  Instead of densifying and reducing, ship exactly those entries
    to their owners with one all-to-all over the static tables built by
    ``core.partition.build_sparse_exchange``.

    Args:
      band: [flat_rows, F] virtual-row partials of this device.
      send_idx: [P, V] this device's rows (band slots) destined for each
        peer; padding slots point at ``flat_rows``.
      recv_idx: [P, V] owned-chunk row for each incoming slot, per peer;
        padding points at ``rows_out`` (trash row).
      topo_or_axes: Topology or axis names (fast -> slow) spanning the
        P = n_data exchange group.
      rows_out: rows of the owned output chunk.

    Returns:
      [rows_out, F] owned chunk with all incoming partials scatter-added.
    """
    topo = _as_topology(topo_or_axes)
    axes = topo.data_axes
    # Pad with one zero row so padding send slots contribute nothing.
    band_pad = jnp.concatenate(
        [band, jnp.zeros((1, band.shape[1]), band.dtype)], axis=0
    )
    msgs = jnp.take(band_pad, send_idx, axis=0)  # [P, V, F]
    # all_to_all: row p of msgs goes to peer p; we receive [P, V, F] where
    # row p came from peer p.
    got = jax.lax.all_to_all(
        msgs, axes, split_axis=0, concat_axis=0, tiled=True
    )
    # Scatter-add into owned chunk (+ trash row for padding slots).
    out = jnp.zeros((rows_out + 1, band.shape[1]), band.dtype)
    out = out.at[recv_idx.reshape(-1)].add(
        got.reshape(-1, band.shape[1]), mode="drop"
    )
    return out[:rows_out]
