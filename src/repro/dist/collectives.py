"""Runtime collectives: thin views over the :class:`CommPlan` ladder.

All three entry points must be called *inside* ``shard_map`` with the
topology's data axes manual.  They accept either a :class:`Topology`
(preferred -- carries link classes and static sizes) or a bare tuple of
mesh axis names, fast -> slow (legacy call sites), which is promoted to a
mesh-less topology resolved for schedule only.

  reduce_partials    dense partial [rows_pad, F] -> owned chunk
                     (direct | rs | hier)
  sparse_exchange    footprint-compressed banded exchange
                     (sparse | hier-sparse)
  hierarchical_psum  all-reduce semantics for gradient sync
                     (direct | rs | hier)

Half-precision wire formats are the caller's choice: cast with
``core.precision.qcast`` (adaptive normalization) before the exchange and
multiply the inverse scale back after -- see ``core/recon.py`` and
``models/lm.py`` for the canonical pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .topology import CommPlan, LINK_CLASSES, Topology

__all__ = ["reduce_partials", "sparse_exchange", "hierarchical_psum"]


def _as_topology(topo_or_axes) -> Topology:
    if isinstance(topo_or_axes, Topology):
        return topo_or_axes
    if isinstance(topo_or_axes, str):
        topo_or_axes = (topo_or_axes,)
    return Topology.from_sizes(
        [(a, _axis_size(a), LINK_CLASSES.get(a, "ici"))
         for a in topo_or_axes]
    )


def _axis_size(axis: str) -> int:
    """Static size of a named axis, resolvable inside a shard_map trace."""
    return int(jax.lax.psum(1, axis))


def reduce_partials(x, topo_or_axes, *, mode: str = "hier"):
    """Reduce per-device dense partials to each device's owned chunk.

    Args:
      x: [rows_pad, F] dense partial (rows_pad divisible by the group
        size; the scatter-add in ``core/recon.py`` produces exactly this).
      topo_or_axes: Topology, or mesh axis names fast -> slow.
      mode: direct | rs | hier.

    Returns:
      [rows_pad / n_data, F] owned chunk, ordered by
      ``jax.lax.axis_index(axes)``.
    """
    topo = _as_topology(topo_or_axes)
    return topo.plan(mode).reduce_partials(x)


def hierarchical_psum(x, topo_or_axes, *, mode: str = "hier"):
    """All-reduce with the plan's schedule (gradient sync).

    ``hier`` realizes the paper's ladder -- reduce-scatter the fast
    levels, all-reduce the slowest at reduced volume, all-gather back --
    on backends whose partitioner supports scatter collectives under
    partially-manual shard_map (TPU); elsewhere it degrades to one
    all-reduce per level (identical values).
    """
    topo = _as_topology(topo_or_axes)
    return topo.plan(mode).psum(x)


def _wire_q8_pack(msgs):
    """Per-(peer, slice) int8 compression for the slow-axis hop.

    ``msgs`` is [n_slow, V2, F]; each (slow peer, fused slice) band gets
    one power-of-two scale steering its max |value| onto the int8 grid
    (floor rounding, so nothing clips -- same construction as
    ``core.precision.quantize_block_vals``).  Returns ``(q, inv)``:
    int8 payload plus the f32 inverse scales [n_slow, 1, F] that ride
    the same all-to-all (4 bytes per (peer, slice) vs 2 per value --
    the ~2x wire saving ``partition.hier_sparse_wire_bytes`` prices).
    """
    m = jnp.max(jnp.abs(msgs.astype(jnp.float32)), axis=1, keepdims=True)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    exp = jnp.clip(jnp.floor(jnp.log2(127.0 / m)), -100, 100)
    scale = jnp.ldexp(jnp.ones_like(m), exp.astype(jnp.int32))
    q = jnp.round(msgs.astype(jnp.float32) * scale).astype(jnp.int8)
    return q, 1.0 / scale


def sparse_exchange(band, send_idx, recv_idx, topo_or_axes, rows_out: int,
                    *, socket_map=None, socket_rows: int | None = None,
                    wire: str = "native"):
    """Footprint-compressed banded exchange (plan modes "sparse" and
    "hier-sparse"), executed as a view over the resolved ``CommPlan``.

    Each device's SpMM emits partials only for the virtual-row band its
    shard touches (an O(1/sqrt(P)) subset of global rows -- paper Fig.
    6-7).  Instead of densifying and reducing, ship exactly those entries
    to their owners:

      sparse        one flat all-to-all over the joint group, tables from
                    ``core.partition.build_sparse_exchange``;
      hier-sparse   two stages over the ladder, tables from
                    ``core.partition.build_hier_sparse_exchange``:
                    socket-level gather/dedup (scatter-add into the
                    socket's merged band, reduce-scatter over the fast
                    axis -- overlapping footprints are summed over the
                    fast link instead of crossing the slow link once per
                    member), then a sparse all-to-all across the slow
                    (node/global) axes, then the local scatter-add.

    Args:
      band: [flat_rows, F] virtual-row partials of this device.
      send_idx: flat: [P, V] band slots destined for each peer (padding
        points at ``flat_rows``); hier: [n_slow, V2] slots of this
        device's merged-band group per slow peer (padding points at
        ``socket_rows``).
      recv_idx: flat: [P, V]; hier: [n_slow, V2].  Owned-chunk row for
        each incoming slot; padding points at ``rows_out`` (trash row).
      topo_or_axes: Topology or axis names (fast -> slow) spanning the
        P = n_data exchange group.
      rows_out: rows of the owned output chunk.
      socket_map: [flat_rows] merged-band slot per band slot (selects the
        hier-sparse path; trash = fast_size * socket_rows).
      socket_rows: W, rows per merged-band group (static; required with
        ``socket_map``).
      wire: "native" ships the slow-axis hop in ``band.dtype``; "q8"
        (hier-sparse only) quantizes each (slow peer, fused slice) band
        to int8 + one f32 inverse scale before the DCI all-to-all and
        widens after -- ~2x less slow-link volume
        (``core.partition.hier_sparse_wire_bytes``).  The fast-axis
        reduce-scatter stays native: ICI bandwidth isn't the bottleneck
        and the merged-band sums should accumulate unquantized.

    Returns:
      [rows_out, F] owned chunk with all incoming partials scatter-added.
    """
    topo = _as_topology(topo_or_axes)
    mode = "sparse" if socket_map is None else "hier-sparse"
    if wire not in ("native", "q8"):
        raise ValueError(f"unknown wire {wire!r}; one of ('native', 'q8')")
    if wire == "q8" and mode != "hier-sparse":
        raise ValueError(
            "wire='q8' compresses the hier-sparse slow-axis hop; the flat "
            "sparse mode has no per-band structure to scale (use "
            "socket_map/socket_rows, or wire='native')"
        )
    plan = topo.plan(mode)
    f = band.shape[1]

    def scatter_out(got):
        # Scatter-add into owned chunk (+ trash row for padding slots).
        out = jnp.zeros((rows_out + 1, f), band.dtype)
        out = out.at[recv_idx.reshape(-1)].add(
            got.reshape(-1, f), mode="drop"
        )
        return out[:rows_out]

    if mode == "sparse":
        (step,) = plan.steps
        # Pad with one zero row so padding send slots contribute nothing.
        band_pad = jnp.concatenate(
            [band, jnp.zeros((1, f), band.dtype)], axis=0
        )
        msgs = jnp.take(band_pad, send_idx, axis=0)  # [P, V, F]
        # all_to_all: row p of msgs goes to peer p; we receive [P, V, F]
        # where row p came from peer p.
        got = jax.lax.all_to_all(
            msgs, step.axes, split_axis=0, concat_axis=0, tiled=True
        )
        return scatter_out(got)

    if socket_rows is None:
        raise ValueError("hier-sparse exchange needs socket_rows (W)")
    rs_step, a2a_step = plan.steps
    g = topo.levels[0].size
    # stage 1: merge the socket's partials into its deduplicated band
    # (grouped by owner fast index) and leave each member its group,
    # summed over the fast link.
    merged = jnp.zeros((g * socket_rows + 1, f), band.dtype)
    merged = merged.at[socket_map].add(band, mode="drop")[:-1]
    mine = jax.lax.psum_scatter(
        merged, rs_step.axes, scatter_dimension=0, tiled=True
    )  # [socket_rows, F]
    # stage 2: sparse all-to-all across the slow axes; every row of my
    # group is owned by a device with my fast index, so it lands on its
    # owner directly.
    mine_pad = jnp.concatenate(
        [mine, jnp.zeros((1, f), band.dtype)], axis=0
    )
    msgs = jnp.take(mine_pad, send_idx, axis=0)  # [n_slow, V2, F]
    if wire == "q8":
        q, inv = _wire_q8_pack(msgs)
        if a2a_step.axes:
            q = jax.lax.all_to_all(
                q, a2a_step.axes, split_axis=0, concat_axis=0, tiled=True
            )
            inv = jax.lax.all_to_all(
                inv, a2a_step.axes, split_axis=0, concat_axis=0,
                tiled=True,
            )
        msgs = (q.astype(jnp.float32) * inv).astype(band.dtype)
    elif a2a_step.axes:
        msgs = jax.lax.all_to_all(
            msgs, a2a_step.axes, split_axis=0, concat_axis=0, tiled=True
        )
    return scatter_out(msgs)
