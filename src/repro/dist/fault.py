"""Fault tolerance & elasticity: stragglers, rebalancing, remeshing.

At the paper's scale (thousands of GPUs, day-long campaigns) the failure
model stops being "a node might die" and becomes "some node is always
slow".  This module provides the host-side substrate:

  * :class:`StragglerMonitor` -- robust (median/MAD) detection of workers
    whose recent step times fall out of the population;
  * :func:`rebalance` -- shrink a straggler's contiguous slice range and
    redistribute, conserving total work;
  * :func:`remesh` -- re-shard a checkpointed pytree onto a different
    mesh (elastic restart after losing nodes);
  * :func:`suggest_checkpoint_period` -- Young/Daly optimal checkpoint
    interval as the system MTBF shrinks with node count.
"""
from __future__ import annotations

import collections
import math

import jax

from .sharding import shardings

__all__ = [
    "StragglerMonitor",
    "rebalance",
    "remesh",
    "suggest_checkpoint_period",
]


class StragglerMonitor:
    """Flag workers whose recent step times are population outliers.

    Each worker's statistic is the mean of its last ``window`` recorded
    times (a mean, not a median, so a single large stall registers
    immediately).  A worker is a straggler when its statistic exceeds
    ``median + k_mad * 1.4826 * MAD`` of all workers' statistics -- the
    usual robust z-score with the MAD scaled to sigma.
    """

    def __init__(self, k_mad: float = 3.0, window: int = 4):
        self.k_mad = float(k_mad)
        self.window = int(window)
        self._times: dict = collections.defaultdict(
            lambda: collections.deque(maxlen=self.window)
        )

    def record(self, worker, seconds: float) -> None:
        self._times[worker].append(float(seconds))

    def stats(self) -> dict:
        return {
            w: sum(ts) / len(ts) for w, ts in self._times.items() if ts
        }

    def stragglers(self) -> list:
        stats = self.stats()
        if len(stats) < 3:  # no meaningful population
            return []
        vals = sorted(stats.values())
        med = _median(vals)
        mad = _median(sorted(abs(v - med) for v in vals))
        # Floor: don't hair-trigger on a near-constant population.
        thresh = med + self.k_mad * 1.4826 * max(mad, 0.01 * med, 1e-12)
        return sorted(w for w, v in stats.items() if v > thresh)


def _median(sorted_vals):
    n = len(sorted_vals)
    mid = n // 2
    if n % 2:
        return sorted_vals[mid]
    return 0.5 * (sorted_vals[mid - 1] + sorted_vals[mid])


def rebalance(ranges: dict, stragglers, shed: float = 0.5) -> dict:
    """Shrink stragglers' slice ranges, redistribute to healthy workers.

    Args:
      ranges: worker -> (start, end) contiguous half-open slice ranges.
      stragglers: workers to shed load from (e.g.
        ``StragglerMonitor.stragglers()``).
      shed: fraction of a straggler's slices to move away.

    Returns:
      New worker -> (start, end) map over the same total span, re-laid-out
      contiguously in worker key order.  Total slice count is conserved,
      and a straggler that had work keeps at least one slice -- even at
      ``shed=1.0`` it sheds load, never its membership (zeroing it out
      would drop it from the mesh, which is ``remesh``'s job, not a
      rebalance).  Empty input maps and empty per-worker ranges are
      both fine (an empty range stays empty, contiguity holds).
    """
    if not ranges:
        return {}
    keys = sorted(ranges)
    sizes = {k: ranges[k][1] - ranges[k][0] for k in keys}
    bad = [k for k in keys if k in set(stragglers)]
    good = [k for k in keys if k not in set(stragglers)]
    if not bad or not good:
        return dict(ranges)
    moved = 0
    for k in bad:
        give = min(int(sizes[k] * shed), max(sizes[k] - 1, 0))
        sizes[k] -= give
        moved += give
    for i in range(moved):  # round-robin keeps healthy loads even
        sizes[good[i % len(good)]] += 1
    start = min(s for s, _ in ranges.values())
    out = {}
    for k in keys:
        out[k] = (start, start + sizes[k])
        start += sizes[k]
    return out


def remesh(tree, specs, mesh):
    """Re-shard a (restored) pytree onto ``mesh`` per ``specs``.

    Values are preserved exactly; only placement changes.  This is the
    elastic-restart path: save on mesh A, lose nodes, restore host-side,
    ``remesh`` onto mesh B (see ``ckpt.checkpoint.restore``).
    """
    return jax.device_put(tree, shardings(specs, mesh))


def suggest_checkpoint_period(
    write_cost_s: float, n_nodes: int, node_mtbf_s: float = 5.0e6
) -> float:
    """Young/Daly first-order optimum: ``sqrt(2 * delta * MTBF_system)``.

    ``MTBF_system = node_mtbf_s / n_nodes`` -- more nodes, more frequent
    failures, shorter optimal period.
    """
    mtbf_sys = node_mtbf_s / max(int(n_nodes), 1)
    return math.sqrt(2.0 * float(write_cost_s) * mtbf_sys)
