"""Declarative communication topology: ``Topology`` and ``CommPlan``.

The paper's hierarchical communication (Sec. III-B) exploits the fact that
a fat node's links form a ladder of speeds: GPUs on one socket talk over
NVLink, sockets within a node over the host bus, and nodes over the
interconnect.  On TPU meshes the same ladder is minor-ICI / major-ICI /
DCI.  A :class:`Topology` names that ladder once -- an ordered (fast ->
slow) list of :class:`Level`, each a mesh axis with a link class -- and a
:class:`CommPlan` resolves a requested reduction *mode* against it into a
schedule of per-level collectives plus a per-level wire-volume model.

Everything downstream is a view over the plan: the runtime collectives
(:mod:`repro.dist.collectives`), the volume accounting in
``benchmarks/bench_comms.py`` (paper Table IV), and the roofline sweeps.

Modes
-----
  direct   one all-reduce over the joint device group; every level's link
           carries the full dense partial.
  rs       one reduce-scatter over the joint group (flat; all links carry
           the full volume, but each device ends with only its chunk).
  hier     the paper's ladder: reduce-scatter level by level, fast ->
           slow; level ``i`` carries ``1 / prod(size of faster levels)``
           of the dense partial -- the local-reduction trick that shrinks
           slow-link traffic by 58-64% in the paper's runs.
  sparse   footprint-compressed all-to-all (beyond-paper): only rows that
           carry partial sums travel, using the static tables from
           ``core.partition.build_sparse_exchange``.
  hier-sparse
           the two paper tricks composed: partials are first merged
           *within the socket level* (union of the members' footprints,
           one deduplicated band per socket, reduce-scattered over the
           fast link), and only the merged band crosses the slower links
           in a sparse all-to-all.  Static tables come from
           ``core.partition.build_hier_sparse_exchange``.

Volume model (documented in docs/dist_api.md): for a dense per-device
partial of ``M`` bytes over ``R`` padded rows, ladder sizes ``g_0`` (the
socket) ... ``g_{L-1}``, flat-sparse pair capacity ``V``, merged socket
band ``G*W`` rows and cross-socket capacity ``V2``:

  direct / rs   level i carries M          (data reduced at every rung)
  hier          level i carries M / prod_{j<i} g_j
  sparse        level i carries M * P*V / R
  hier-sparse   socket level carries M * G*W / R; every slower level
                carries M * n_slow*V2 / R   (n_slow = P / G)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Level",
    "Topology",
    "CommStep",
    "CommPlan",
    "MODES",
    "LINK_CLASSES",
]

MODES = ("direct", "rs", "hier", "sparse", "hier-sparse")

# Canonical link class per production mesh axis: the minor ICI axis is
# the paper's "socket", the major ICI axis its "node", DCI its "global"
# level.  ``launch.mesh.mesh_axis_classes`` derives from this table.
LINK_CLASSES = {"model": "ici", "data": "ici", "pod": "dci"}


@dataclasses.dataclass(frozen=True)
class Level:
    """One rung of the communication ladder (fast -> slow order)."""

    axis: str  # mesh axis name
    size: int  # devices along this axis
    link: str  # "ici" | "dci"
    paper_level: str  # "socket" | "node" | "global"


@dataclasses.dataclass(frozen=True)
class Topology:
    """A mesh's communicating axes, ordered fast -> slow, plus the axes
    that carry communication-free (batch) parallelism.

    Build with :meth:`from_mesh` (binds a jax Mesh, required for running
    collectives) or :meth:`from_sizes` (pure accounting, e.g. volume
    tables for a machine that is not attached).
    """

    levels: tuple  # tuple[Level, ...], fast -> slow
    batch_axes: tuple = ()
    mesh: object = None  # jax Mesh | None

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_mesh(
        cls,
        mesh,
        data_axes: Sequence[str] = ("model",),
        batch_axes: Sequence[str] = ("data",),
        link_classes: dict | None = None,
    ) -> "Topology":
        """Build from a jax Mesh.

        ``data_axes`` (fast -> slow) carry the in-slice partial-data
        reduction; ``batch_axes`` carry slice/batch parallelism and never
        communicate.  ``link_classes`` maps axis -> "ici" | "dci";
        defaults come from the canonical :data:`LINK_CLASSES` table.
        """
        links = dict(LINK_CLASSES)
        links.update(link_classes or {})
        data_axes = tuple(data_axes)
        for a in data_axes + tuple(batch_axes):
            if a not in mesh.shape:
                raise ValueError(
                    f"axis {a!r} not in mesh axes {tuple(mesh.shape)}"
                )
        levels = _make_levels(
            [(a, mesh.shape[a], links.get(a, "ici")) for a in data_axes]
        )
        return cls(
            levels=levels, batch_axes=tuple(batch_axes), mesh=mesh
        )

    @classmethod
    def from_sizes(cls, sizes: Sequence) -> "Topology":
        """Meshless topology from ``[(axis, size, link), ...]`` fast ->
        slow (link defaults to "ici" for 2-tuples)."""
        norm = [
            (s[0], int(s[1]), s[2] if len(s) > 2 else "ici")
            for s in sizes
        ]
        return cls(levels=_make_levels(norm))

    # ------------------------------------------------------------------ #
    # interrogation
    # ------------------------------------------------------------------ #
    @property
    def data_axes(self) -> tuple:
        """Communicating mesh axes, fast -> slow."""
        return tuple(lv.axis for lv in self.levels)

    @property
    def n_data(self) -> int:
        """Total devices in the reduction group."""
        return math.prod(lv.size for lv in self.levels)

    @property
    def n_batch(self) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.batch_axes)

    def plan(self, mode: str, *, pair_slots: int | None = None,
             dense_rows: int | None = None,
             merged_rows: int | None = None,
             cross_rows: int | None = None,
             wire: str = "native",
             comm_bytes: int = 2) -> "CommPlan":
        """Resolve ``mode`` into a :class:`CommPlan`.

        The sparse modes additionally need static table capacities to
        model wire volume (runtime execution works without them):
        ``sparse`` takes ``pair_slots`` (V of ``build_sparse_exchange``)
        and ``dense_rows`` (padded global rows); ``hier-sparse`` takes
        ``merged_rows`` (G*W, the padded per-socket merged band of
        ``build_hier_sparse_exchange``) and ``cross_rows`` (n_slow*V2,
        per-device rows crossing the slow links) plus ``dense_rows``.
        ``core.partition.exchange_volume_params`` computes all four from
        an operator shard (exact tables when built, estimates for
        abstract plans).

        ``wire="q8"`` (hier-sparse only) prices the compressed slow-axis
        hop of ``collectives.sparse_exchange(wire="q8")``: int8 payload
        plus one f32 scale per (slow peer, slice), relative to a native
        wire of ``comm_bytes``-wide values (the policy's ``comm_bytes``).
        """
        return CommPlan.resolve(
            self, mode, pair_slots=pair_slots, dense_rows=dense_rows,
            merged_rows=merged_rows, cross_rows=cross_rows,
            wire=wire, comm_bytes=comm_bytes,
        )

    def describe(self) -> str:
        """Human-readable ladder summary (one line per level)."""
        rows = [
            f"  {lv.paper_level:>6s}: axis {lv.axis!r} x{lv.size} "
            f"({lv.link})"
            for lv in self.levels
        ]
        head = (
            f"Topology over {self.n_data} devices"
            + (f", batch axes {self.batch_axes}" if self.batch_axes
               else "")
        )
        return "\n".join([head] + rows)


def _make_levels(sizes) -> tuple:
    """Assign paper levels: fastest ICI axis = socket, later ICI = node,
    DCI = global."""
    levels = []
    for i, (axis, size, link) in enumerate(sizes):
        if link == "dci":
            paper = "global"
        elif i == 0:
            paper = "socket"
        else:
            paper = "node"
        levels.append(
            Level(axis=axis, size=int(size), link=link, paper_level=paper)
        )
    return tuple(levels)


# --------------------------------------------------------------------- #
# plans
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class CommStep:
    """One collective of a resolved schedule.

    ``wire_frac`` is the fraction of the dense per-device partial that
    crosses this step's (slowest) link, per device -- reduce-semantics
    accounting as in the paper's Table IV, not ring-hop counting.
    """

    op: str  # all_reduce | reduce_scatter | all_gather | all_to_all
    axes: tuple  # mesh axes the collective spans
    link: str  # slowest link class crossed
    wire_frac: float


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A reduction mode resolved against a topology.

    ``steps`` is the execution schedule (consumed by
    ``dist.collectives``); ``level_fracs`` is the per-level wire-volume
    model (consumed by benchmarks and the roofline sweeps): entry ``i`` is
    the fraction of the dense partial that crosses level ``i``'s link.
    """

    topology: Topology
    mode: str
    steps: tuple  # tuple[CommStep, ...]
    level_fracs: tuple  # tuple[float, ...], aligned with topology.levels

    # ------------------------------------------------------------------ #
    # resolution
    # ------------------------------------------------------------------ #
    @classmethod
    def resolve(cls, topo: Topology, mode: str, *,
                pair_slots: int | None = None,
                dense_rows: int | None = None,
                merged_rows: int | None = None,
                cross_rows: int | None = None,
                wire: str = "native",
                comm_bytes: int = 2) -> "CommPlan":
        if mode not in MODES:
            raise ValueError(f"unknown comm mode {mode!r}; one of {MODES}")
        if wire not in ("native", "q8"):
            raise ValueError(
                f"unknown wire {wire!r}; one of ('native', 'q8')"
            )
        if wire == "q8" and mode != "hier-sparse":
            raise ValueError(
                "wire='q8' compresses the hier-sparse slow-axis hop only "
                "(other modes ship dense partials; quantize via the "
                "precision policy's comm dtype instead)"
            )
        levels = topo.levels
        axes = topo.data_axes
        slowest = levels[-1].link if levels else "ici"
        if mode == "direct":
            steps = (CommStep("all_reduce", axes, slowest, 1.0),)
            fracs = tuple(1.0 for _ in levels)
        elif mode == "rs":
            steps = (CommStep("reduce_scatter", axes, slowest, 1.0),)
            fracs = tuple(1.0 for _ in levels)
        elif mode == "hier":
            steps, fracs = [], []
            frac = 1.0
            for lv in levels:
                steps.append(
                    CommStep("reduce_scatter", (lv.axis,), lv.link, frac)
                )
                fracs.append(frac)
                frac /= lv.size
            steps, fracs = tuple(steps), tuple(fracs)
        elif mode == "sparse":
            if pair_slots is not None and dense_rows:
                frac = topo.n_data * pair_slots / float(dense_rows)
            else:
                frac = float("nan")  # volume model needs the tables
            steps = (CommStep("all_to_all", axes, slowest, frac),)
            fracs = tuple(frac for _ in levels)
        else:  # hier-sparse: socket-level dedup, then cross-socket a2a
            if not levels:
                raise ValueError("hier-sparse needs at least one level")
            sock = levels[0]
            if merged_rows is not None and dense_rows:
                sock_frac = merged_rows / float(dense_rows)
            else:
                sock_frac = float("nan")
            if cross_rows is not None and dense_rows:
                if wire == "q8":
                    # int8 values + one f32 inverse scale per slow peer
                    # (per slice), as a fraction of the *native* dense
                    # frame (dense_rows at comm_bytes wide) so level
                    # fractions stay comparable across wire formats
                    # (core.partition.hier_sparse_wire_bytes).
                    n_slow = max(
                        1, math.prod(lv.size for lv in levels[1:])
                    )
                    cross_frac = (cross_rows * 1 + n_slow * 4) / (
                        float(dense_rows) * comm_bytes
                    )
                else:
                    cross_frac = cross_rows / float(dense_rows)
            else:
                cross_frac = float("nan")
            steps = (
                CommStep(
                    "reduce_scatter", (sock.axis,), sock.link, sock_frac
                ),
                CommStep("all_to_all", axes[1:], slowest, cross_frac),
            )
            fracs = (sock_frac,) + tuple(cross_frac for _ in levels[1:])
        return cls(
            topology=topo, mode=mode, steps=steps, level_fracs=fracs
        )

    # ------------------------------------------------------------------ #
    # volume model (paper Table IV)
    # ------------------------------------------------------------------ #
    def level_bytes(self, dense_bytes: float) -> tuple:
        """Per-level wire bytes for one reduction of a ``dense_bytes``
        partial, aligned with ``topology.levels``."""
        return tuple(f * dense_bytes for f in self.level_fracs)

    def wire_bytes_by_link(self, dense_bytes: float) -> dict:
        """Aggregate wire bytes per link class ("ici" / "dci")."""
        out: dict = {}
        for lv, b in zip(self.topology.levels,
                         self.level_bytes(dense_bytes)):
            out[lv.link] = out.get(lv.link, 0.0) + b
        return out

    def slow_link_bytes(self, dense_bytes: float) -> float:
        """Bytes crossing the slowest (last) level's link -- the quantity
        the paper's hierarchical scheme minimizes."""
        return self.level_bytes(dense_bytes)[-1]

    def describe(self) -> str:
        lines = [f"CommPlan(mode={self.mode!r})"]
        for s in self.steps:
            lines.append(
                f"  {s.op:>14s} over {s.axes} [{s.link}] "
                f"wire x{s.wire_frac:.4g}"
            )
        return "\n".join(lines)

    # ------------------------------------------------------------------ #
    # ladder engine (call inside shard_map over the manual data axes)
    # ------------------------------------------------------------------ #
    def reduce_partials(self, x):
        """Dense partial [rows_pad, F] -> this device's owned chunk
        [rows_pad / n_data, F].

        Chunk ownership follows ``jax.lax.axis_index(data_axes)``
        linearization (first axis major), matching the partition plan's
        device order under a ``PartitionSpec((data_axes,))`` sharding.
        """
        if self.mode in ("sparse", "hier-sparse"):
            raise ValueError(
                f"{self.mode} mode reduces via "
                "dist.collectives.sparse_exchange (needs the static "
                "footprint tables)"
            )
        axes = self.topology.data_axes
        p = self.topology.n_data
        if x.shape[0] % p:
            raise ValueError(
                f"rows {x.shape[0]} not divisible by group size {p}"
            )
        for step in self.steps:
            if step.op == "all_reduce":
                x = jax.lax.psum(x, step.axes)
                i = jax.lax.axis_index(axes)
                x = jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // p), x.shape[0] // p, axis=0
                )
            elif step.op == "reduce_scatter":
                x = jax.lax.psum_scatter(
                    x, step.axes, scatter_dimension=0, tiled=True
                )
            else:  # pragma: no cover - resolve() emits only the above
                raise AssertionError(step.op)
        return x

    def psum(self, x):
        """All-reduce semantics (same shape out, fully summed), scheduled
        per the plan.

        ``hier`` lowers to reduce-scatter fast levels / all-reduce the
        slowest / all-gather back (the paper's gradient-sync ladder) when
        the backend supports scatter collectives under partially-manual
        shard_map; elsewhere it falls back to one all-reduce per level
        (identical values, hierarchical schedule, full volume on every
        link -- the fallback is a correctness path, not a perf path).
        """
        axes = self.topology.data_axes
        if not axes:
            return x
        if self.mode == "direct" or len(axes) == 1:
            return jax.lax.psum(x, axes)
        if self.mode in ("sparse", "hier-sparse"):
            raise ValueError(f"{self.mode} mode has no psum form")
        if not _scatter_collectives_ok():
            for lv in self.topology.levels:
                x = jax.lax.psum(x, lv.axis)
            return x
        if self.mode == "rs":
            return _rs_ag_psum(x, [axes], self.topology.n_data)
        # hier: scatter down the fast levels, all-reduce the slowest
        fast_levels = self.topology.levels[:-1]
        return _rs_ag_psum(
            x,
            [(lv.axis,) for lv in fast_levels],
            math.prod(lv.size for lv in fast_levels),
            last=self.topology.levels[-1].axis,
        )


def _scatter_collectives_ok() -> bool:
    # XLA:CPU's SPMD partitioner aborts on reduce-scatter / all-gather
    # inside partially-manual shard_map (observed through 0.4.x); TPU is
    # the paper target and handles them.
    return jax.default_backend() == "tpu"


def _rs_ag_psum(x, scatter_groups, group: int, last: str | None = None):
    """Flatten-pad ladder: reduce-scatter each group (fast -> slow),
    optionally all-reduce ``last``, then all-gather back in reverse.
    ``group`` is the static product of all scattered axis sizes."""
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % group
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((pad,), flat.dtype)]
        )
    for axes in scatter_groups:
        flat = jax.lax.psum_scatter(
            flat, axes, scatter_dimension=0, tiled=True
        )
    if last is not None:
        flat = jax.lax.psum(flat, last)
    for axes in reversed(scatter_groups):
        flat = jax.lax.all_gather(flat, axes, axis=0, tiled=True)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)
