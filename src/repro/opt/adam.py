"""Pure-JAX AdamW with fp32 master state and optional bf16 params.

No optax dependency.  State is a pytree mirroring params; the optimizer is
sharding-transparent (state inherits param PartitionSpecs), which is what
keeps it viable at 512+ chips: per-device optimizer memory is
3x the param shard (m, v, master) regardless of topology.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "sgd_momentum"]


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def init(self, params) -> dict:
        zeros = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), t
        )
        return {
            "m": zeros(params),
            "v": zeros(params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params) -> tuple[Any, dict]:
        count = state["count"] + 1
        if self.grad_clip > 0:
            gsq = jax.tree.reduce(
                lambda a, b: a + b,
                jax.tree.map(
                    lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads
                ),
            )
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        else:
            scale = jnp.float32(1.0)

        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda g, m: self.b1 * m
            + (1 - self.b1) * g.astype(jnp.float32) * scale,
            grads, state["m"],
        )
        new_v = jax.tree.map(
            lambda g, v: self.b2 * v
            + (1 - self.b2) * (g.astype(jnp.float32) * scale) ** 2,
            grads, state["v"],
        )

        def upd(p, m, v):
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, {"m": new_m, "v": new_v, "count": count}


def sgd_momentum(lr: float = 0.1, mu: float = 0.9):
    """Minimal SGD+momentum (used by tests as a second optimizer)."""

    class _SGD:
        def init(self, params):
            return {
                "mom": jax.tree.map(
                    lambda x: jnp.zeros_like(x, jnp.float32), params
                )
            }

        def update(self, grads, state, params):
            mom = jax.tree.map(
                lambda b, g: mu * b + g.astype(jnp.float32),
                state["mom"], grads,
            )
            new_p = jax.tree.map(
                lambda p, b: (p.astype(jnp.float32) - lr * b).astype(
                    p.dtype
                ),
                params, mom,
            )
            return new_p, {"mom": mom}

    return _SGD()
