"""Optimizers."""
