"""The paper's four experimental datasets (Table II) as configs.

Dimensions are K (projections) x M (vertical detector rows == slices) x
N (horizontal channels).  ``mini`` variants are used by CPU benchmarks;
the full shapes drive the dry-run via analytic shard-shape estimation.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class XCTDataset:
    name: str
    k: int  # projection angles
    m: int  # slices (detector rows)
    n: int  # detector channels == image side
    # suggested production partitioning (paper Sec. IV-B/E)
    p_data: int = 256
    open_data: bool = True


DATASETS = {
    "xct-shale": XCTDataset("xct-shale", 1501, 1792, 2048, p_data=64),
    "xct-chip": XCTDataset(
        "xct-chip", 1210, 1024, 2448, p_data=64, open_data=False
    ),
    "xct-charcoal": XCTDataset(
        "xct-charcoal", 4500, 4198, 6613, p_data=256
    ),
    "xct-brain": XCTDataset(
        "xct-brain", 4501, 9209, 11283, p_data=512, open_data=False
    ),
}
