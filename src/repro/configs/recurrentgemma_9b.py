"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048
[arXiv:2402.19427; unverified].  38 = 12 full (rec, rec, local) periods + a
trailing (rec, rec) partial period (unrolled).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim_override=256,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    rnn_width=4096,
    act="gelu",
    gated_mlp=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=5,  # 1 period + (rec, rec) remainder
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim_override=16,
    block_pattern=("rglru", "rglru", "local"),
    window=16,
    rnn_width=64,
    act="gelu",
    gated_mlp=True,
    conv_width=2,
)
