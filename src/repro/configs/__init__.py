"""Config registry: ``--arch <id>`` resolution for all assigned archs."""
from __future__ import annotations

import importlib

from .base import ArchConfig  # noqa: F401
from .xct_datasets import DATASETS as XCT_DATASETS  # noqa: F401

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "smollm-135m": "smollm_135m",
    "xlstm-350m": "xlstm_350m",
}

ARCH_NAMES = tuple(_MODULES)

# (seq_len, global_batch, step kind) per assigned input shape
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str, smoke: bool = False, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
