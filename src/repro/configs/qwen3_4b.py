"""qwen3-4b [dense] -- qk_norm, GQA.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, head_dim 128
[hf:Qwen/Qwen3-8B; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim_override=128,
    qk_norm=True,
)

SMOKE = ArchConfig(
    name="qwen3-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim_override=16,
    qk_norm=True,
)
