"""qwen2-vl-7b [vlm] -- M-RoPE, dynamic resolution.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
[arXiv:2409.12191; hf].  Backbone only: the vision frontend is a stub --
``input_specs`` feeds precomputed patch embeddings; M-RoPE's three position
streams (t/h/w) all receive the text position ids, exactly M-RoPE's
behaviour on text tokens.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    embed_inputs=False,
)

SMOKE = ArchConfig(
    name="qwen2-vl-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim_override=16,
    rope="mrope",
    mrope_sections=(2, 3, 3),
    qkv_bias=True,
    embed_inputs=False,
)
