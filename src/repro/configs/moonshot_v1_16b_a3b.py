"""moonshot-v1-16b-a3b [moe] -- kimi/moonlight, 64 experts top-6.

48L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
)

SMOKE = ArchConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    moe_experts=8,
    moe_top_k=2,
    moe_d_ff=96,
)
