"""Architecture configuration schema shared by all assigned archs."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture + runtime configuration.

    ``block_pattern`` is cycled over layers (e.g. recurrentgemma's
    ``("rglru", "rglru", "local")``); layers are scanned period-wise with a
    trailing partial period unrolled.
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim_override: int | None = None
    block_pattern: tuple = ("attn",)
    window: int = 0  # sliding window for "local" blocks
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: str = "rope"  # rope | mrope | sinusoidal | none
    mrope_sections: tuple = (16, 24, 24)
    # channel mixing
    act: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    # embeddings
    embed_inputs: bool = True  # False: frontend stub feeds embeddings
    tie_embeddings: bool = False
    # recurrent blocks
    rnn_width: int | None = None
    conv_width: int = 4
    mlstm_expansion: int = 2
    slstm_ff_factor: float = 1.3334
    # runtime knobs (not architecture identity)
    max_cache: int = 0  # KV capacity for prefill/decode lowering
    cache_dtype: object = jnp.bfloat16
    activation_dtype: object = jnp.bfloat16
    remat: str = "none"  # none | full | dots
    # scan_layers=True gives compact HLO (fast compile); False unrolls the
    # layer stack so compiled cost_analysis counts every layer (XLA counts
    # a scan body once -- measured; see EXPERIMENTS.md §Dry-run notes).
    scan_layers: bool = True
    # SPMD sharding hints (EXPERIMENTS.md §Perf): anchor attention logits
    # and MoE dispatch tensors so XLA's propagation cannot replicate them.
    # attn_heads_merge: shard scores over the merged (kv x group) head dim
    # (kv alone doesn't divide the model axis but total heads do).
    # attn_q_shard: shard scores over query-time (neither kv nor total
    # heads divide the model axis).
    shard_hints: bool = False
    attn_q_shard: bool = False
    attn_heads_merge: bool = False
    dp_axes: tuple = ("pod", "data")

    @property
    def head_dim(self) -> int:
        return self.head_dim_override or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if no full-attention block (long-context decode viable)."""
        return "attn" not in self.block_pattern

    @property
    def pattern_kinds(self) -> tuple:
        return tuple(
            self.block_pattern[i % len(self.block_pattern)]
            for i in range(self.n_layers)
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline bookkeeping)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        if self.embed_inputs:
            n += self.vocab_size * d
        n += d * self.vocab_size  # unembed (tied -> still counted once)
        if self.tie_embeddings and self.embed_inputs:
            n -= d * self.vocab_size
        for kind in self.pattern_kinds:
            if kind in ("attn", "local"):
                n += d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                if self.moe_experts:
                    n += d * self.moe_experts  # router
                    n += (
                        self.moe_experts * 3 * d * self.moe_d_ff
                    )
                else:
                    n += d * self.d_ff * (3 if self.gated_mlp else 2)
            elif kind == "rglru":
                r = self.rnn_width or d
                n += 2 * d * r + 2 * r * r + r * d  # branches+gates+out
                n += d * self.d_ff * (3 if self.gated_mlp else 2)
            elif kind == "mlstm":
                dn = self.mlstm_expansion * d
                n += 2 * d * dn + 3 * dn * dn + dn * d
            elif kind == "slstm":
                f = int(self.slstm_ff_factor * d)
                n += 8 * d * d + d * 2 * f + f * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of the expert pool)."""
        if not self.moe_experts:
            return self.param_count()
        n = self.param_count()
        n_layers_moe = sum(
            1 for k in self.pattern_kinds if k in ("attn", "local")
        )
        full = n_layers_moe * self.moe_experts * 3 * self.d_model * (
            self.moe_d_ff
        )
        active = n_layers_moe * self.moe_top_k * 3 * self.d_model * (
            self.moe_d_ff
        )
        return n - full + active
