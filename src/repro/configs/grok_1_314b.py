"""grok-1-314b [moe] -- 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) expert d_ff=32768 vocab=131072
[hf:xai-org/grok-1; unverified].  8 experts do not divide the 16-wide
model axis; the sharding rules fall back to ffn-dim tensor parallelism
inside each expert (see dist/sharding.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    moe_experts=8,
    moe_top_k=2,
    moe_d_ff=32768,
)

SMOKE = ArchConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe_experts=4,
    moe_top_k=2,
    moe_d_ff=128,
)
