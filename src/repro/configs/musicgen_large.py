"""musicgen-large [audio] -- decoder-only over EnCodec tokens.

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf].  Backbone only: the EnCodec frontend is a stub --
``input_specs`` feeds precomputed frame embeddings.  Plain (non-gated) GELU
MLP, LayerNorm, sinusoidal positions, per the MusicGen transformer.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    rope="sinusoidal",
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    embed_inputs=False,
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    rope="sinusoidal",
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    embed_inputs=False,
)
