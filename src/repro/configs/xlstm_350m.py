"""xlstm-350m [ssm] -- sLSTM + mLSTM blocks, 7:1 ratio.

24L d_model=1024 4H d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
d_ff = 0: xLSTM blocks carry their own projections (mLSTM pf=2 up/down,
sLSTM gated FFN pf=4/3); there is no separate MLP block.
"""
from .base import ArchConfig

_PATTERN = ("mlstm",) * 7 + ("slstm",)

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
)

SMOKE = ArchConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    block_pattern=("mlstm", "slstm"),
    conv_width=2,
)
