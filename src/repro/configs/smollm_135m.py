"""smollm-135m [dense] -- llama-arch small.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf].  Also the ~100M-class model used by
the end-to-end training example.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="smollm-135m-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_ff=96,
    vocab_size=512,
    tie_embeddings=True,
)
