"""Modeled-tier config autotuner: sweep knobs, pick argmin, mint passport.

The sweep axes are exactly the knobs the rest of the stack already
exposes -- kernel block shape ``(R, K)``, slab budget fraction, comm
mode, window-DMA mode, and window-slot order -- and every candidate is
priced by the SAME shared models the roofline sweeps and CI gates pin:

  * ``core.partition.estimate_plan``  -- allocation-free shard shapes;
  * ``kernels.traffic.spmm_traffic`` + ``dma_issue_seconds``  -- HBM
    bytes and DMA-issue seconds of the fused SpMM (slot-order aware);
  * ``launch.xct_perf.comm_volume``  -- per-link-class wire bytes under
    the production topology ladder;
  * ``stream.scheduler.suggest_slab``  -- slab feasibility under the
    byte budget (an infeasible candidate is skipped, not crashed on).

Because the models are closed-form, the *modeled tier needs no
accelerator*: tuning for a 512-device pod runs on a laptop.  An
optional measured tier (``measure=`` callable) re-ranks the top modeled
candidates by wall clock on real hardware -- but never silently: the
traffic module warns when interpret-mode timings are used to rank dma
modes (see ``spmm_traffic(interpret_timed=True)``).

The argmin is deterministic: the space is enumerated in a fixed nested
order and ties keep the first winner, so two runs of the same sweep
mint byte-identical passports (pinned by ``tests/test_tune.py`` and the
CI tune-smoke gate).
"""
from __future__ import annotations

import math

from ..core.partition import (
    SLOT_ORDERS,
    PartitionConfig,
    default_socket,
    estimate_plan,
)
from ..core.precision import get_policy
from ..kernels.traffic import (
    DMA_MODES,
    PER_COPY_OVERHEAD_S,
    dma_issue_seconds,
    spmm_traffic,
)
from ..launch.hlo_analysis import HW
from .passport import (
    TuningPassport,
    describe_hardware,
    hardware_fingerprint,
)

__all__ = ["DEFAULT_SPACE", "modeled_objective", "autotune"]

# Non-overlapped cost of one slab boundary (prefetch warmup + solver
# re-entry): a model constant that makes the slab-size axis meaningful
# -- bigger slabs amortize more boundaries -- without pretending to
# know a filesystem.  Candidates differing only in slab_frac tie on
# kernel/comm seconds and split on this term.
SLAB_BOUNDARY_S = 1e-3

DEFAULT_SPACE = {
    "block": [(32, 32), (64, 64)],  # (rows_per_block, nnz_per_stage)
    "tile": [8],  # Hilbert patch side; widen at production scale
    "slab_frac": [1.0, 0.5, 0.25],  # fraction of mem_budget per slab
    "comm_mode": ["direct", "rs", "hier", "sparse", "hier-sparse"],
    "dma": list(DMA_MODES),
    "slot_order": list(SLOT_ORDERS),
    # precision ladder rungs worth sweeping: the paper's mixed default
    # vs the quantized operator tier (int8 vals + per-block scales)
    "precision": ["mixed", "q8"],
    # hier-sparse slow-axis wire: native comm dtype vs int8+scale
    # compression (only paired with comm_mode="hier-sparse")
    "wire": ["native", "q8"],
}


def modeled_objective(
    geo,
    knobs: dict,
    *,
    p_data: int,
    topology,
    mem_budget: int,
    fuse: int = 16,
    precision: str = "mixed",
    n_slices: int | None = None,
    per_copy_overhead_s: float = PER_COPY_OVERHEAD_S,
    _plan_cache: dict | None = None,
) -> dict:
    """Price one knob setting; raises ``ValueError`` when infeasible.

    Returns the per-iteration modeled seconds of one full volume pass
    (``total_seconds``) plus its auditable terms: ``dma_issue_seconds``
    (the issue-overhead term run-length coalescing and slot reordering
    attack), ``hbm_seconds``, ``ici_seconds``/``dci_seconds`` (from the
    per-link wire bytes, also returned), the granted ``y_slab`` and
    slab count.  All terms per device.
    """
    from ..core.recon import ReconConfig
    from ..launch.xct_perf import comm_volume
    from ..stream.scheduler import suggest_slab

    r, k = knobs["block"]
    key = (r, k, knobs["tile"], knobs["slot_order"])
    cache = _plan_cache if _plan_cache is not None else {}
    if key not in cache:
        cache[key] = estimate_plan(
            geo,
            PartitionConfig(
                n_data=p_data, tile=knobs["tile"], rows_per_block=r,
                nnz_per_stage=k, socket=default_socket(p_data, p_data),
                slot_order=knobs["slot_order"],
            ),
        )
    plan = cache[key]
    prec = knobs.get("precision", precision)
    wire_fmt = knobs.get("wire", "native")
    pol = get_policy(prec)
    rcfg = ReconConfig(
        precision=prec, comm_mode=knobs["comm_mode"], fuse=fuse,
        dma=knobs["dma"], wire=wire_fmt,
    )
    budget = int(mem_budget * knobs["slab_frac"])
    sp = suggest_slab(
        plan, rcfg, topology, budget, n_slices=n_slices,
    )  # ValueError here = candidate infeasible under its slab budget

    issue_s = hbm_s = 0.0
    for op in (plan.proj, plan.back):
        _, b, s, rr, kk = op.inds.shape
        t = spmm_traffic(
            b, s, rr, kk, op.winmap.shape[-1], fuse,
            storage_bytes=pol.storage_bytes,
            vals_bytes=pol.vals_bytes, staging="fused",
            dma=knobs["dma"], slot_order=knobs["slot_order"],
        )
        issue_s += t["dma_issues"] * per_copy_overhead_s
        hbm_s += t["hbm_bytes"] / HW.hbm_bw
    wire = comm_volume(
        plan, knobs["comm_mode"], fuse, pol.comm_bytes, topology,
        wire=wire_fmt,
    )
    ici_s = wire["ici"] / HW.ici_bw
    dci_s = wire["dci"] / HW.dci_bw

    minis = sp.y_slab // sp.granule
    n_slabs = (
        int(math.ceil(n_slices / sp.y_slab)) if n_slices else 1
    )
    # minibatches for the WHOLE volume count granules of n_slices: the
    # last slab is partial, so slabs x full-slab minis would overbill
    # exactly the candidates whose smaller operator grew y_slab
    total_minis = (
        int(math.ceil(n_slices / sp.granule)) if n_slices else minis
    )
    per_mini = issue_s + hbm_s + ici_s + dci_s
    total = per_mini * total_minis + n_slabs * SLAB_BOUNDARY_S
    return {
        "total_seconds": total,
        "dma_issue_seconds": issue_s,
        "hbm_seconds": hbm_s,
        "ici_seconds": ici_s,
        "dci_seconds": dci_s,
        "ici_bytes": wire["ici"],
        "dci_bytes": wire["dci"],
        "y_slab": int(sp.y_slab),
        "n_slabs": n_slabs,
    }


def _baseline_knobs(space: dict) -> dict:
    """The untuned reference: stock runtime defaults on the legacy
    first-seen layout (what every job ran before the tuner existed)."""
    return {
        "block": (32, 32),
        "tile": space["tile"][0],
        "slab_frac": 1.0,
        "comm_mode": "hier",
        "dma": "coalesced",
        "slot_order": "first_seen",
        "precision": "mixed",
        "wire": "native",
    }


def autotune(
    geo,
    *,
    p_data: int = 1,
    topology=None,
    mem_budget: int,
    n_slices: int | None = None,
    fuse: int = 16,
    precision: str = "mixed",
    space: dict | None = None,
    per_copy_overhead_s: float | None = None,
    overhead_source: str | None = None,
    measure=None,
    hardware: dict | None = None,
) -> tuple[TuningPassport, list[dict]]:
    """Sweep the knob space, mint the argmin passport.

    Args:
      geo: ``core.geometry.XCTGeometry`` of the target workload.
      p_data: in-slice data-parallel devices to plan for.
      topology: ``dist.Topology``; default is the meshless production
        ladder ``launch.xct_perf.sweep_topology(p_data)``.
      mem_budget: bytes available per device for operator + slabs.
      n_slices: volume depth (enables the slab-amortization term).
      space: sweep axes, same keys as :data:`DEFAULT_SPACE` (missing
        keys take the defaults).
      per_copy_overhead_s / overhead_source: calibrated DMA issue
        overhead (see ``benchmarks.bench_spmm.
        calibrate_per_copy_overhead``); defaults to the traffic-model
        constant, recorded as ``overhead_source="default"``.
      measure: optional ``measure(knobs) -> seconds`` callable; when
        given, the top 3 modeled candidates are re-ranked by it
        (measured tier).
      hardware: override :func:`passport.describe_hardware` (tests).

    Returns ``(passport, trials)``: the minted (NOT yet saved) passport
    and the full trial log, one dict per candidate, infeasible ones
    included with ``feasible=False``.
    """
    if topology is None:
        from ..launch.xct_perf import sweep_topology

        topology = sweep_topology(p_data)
    sp = dict(DEFAULT_SPACE)
    sp.update(space or {})
    if "precision" not in (space or {}) and precision != "mixed":
        # an explicit precision= restricts the axis (legacy callers
        # tuned FOR a policy; a space override still wins)
        sp["precision"] = [precision]
    overhead = (
        PER_COPY_OVERHEAD_S
        if per_copy_overhead_s is None
        else float(per_copy_overhead_s)
    )
    source = overhead_source or (
        "default" if per_copy_overhead_s is None else "measured"
    )

    plan_cache: dict = {}
    common = dict(
        p_data=p_data, topology=topology, mem_budget=mem_budget,
        fuse=fuse, precision=precision, n_slices=n_slices,
        per_copy_overhead_s=overhead, _plan_cache=plan_cache,
    )
    trials: list[dict] = []
    best = None  # (total, trial) -- strict < keeps the first winner
    for block in sp["block"]:
        for tile in sp["tile"]:
            for slot_order in sp["slot_order"]:
                for dma in sp["dma"]:
                    for comm_mode in sp["comm_mode"]:
                        for prec in sp["precision"]:
                            for wire in sp["wire"]:
                                # q8 wire compresses the hier-sparse
                                # slow hop; other modes have none, so
                                # the combo duplicates wire="native"
                                if (wire != "native"
                                        and comm_mode != "hier-sparse"):
                                    continue
                                for slab_frac in sp["slab_frac"]:
                                    knobs = {
                                        "block": tuple(block),
                                        "tile": tile,
                                        "slot_order": slot_order,
                                        "dma": dma,
                                        "comm_mode": comm_mode,
                                        "precision": prec,
                                        "wire": wire,
                                        "slab_frac": slab_frac,
                                    }
                                    try:
                                        obj = modeled_objective(
                                            geo, knobs, **common
                                        )
                                    except ValueError:
                                        trials.append(
                                            {**knobs, "feasible": False}
                                        )
                                        continue
                                    trial = {
                                        **knobs, **obj, "feasible": True
                                    }
                                    trials.append(trial)
                                    if best is None or (
                                        obj["total_seconds"] < best[0]
                                    ):
                                        best = (
                                            obj["total_seconds"], trial
                                        )
    if best is None:
        raise ValueError(
            f"no feasible candidate under mem_budget={mem_budget}; "
            "the operator alone may overflow every slab fraction"
        )
    if measure is not None:
        top = sorted(
            (t for t in trials if t["feasible"]),
            key=lambda t: t["total_seconds"],
        )[:3]
        timed = [(measure({k: t[k] for k in (
            "block", "tile", "slot_order", "dma", "comm_mode",
            "precision", "wire", "slab_frac")}), t) for t in top]
        best = (best[0], min(timed, key=lambda x: x[0])[1])

    win = best[1]
    try:
        base = modeled_objective(geo, _baseline_knobs(sp), **common)
    except ValueError:
        base = None
    hw = hardware if hardware is not None else describe_hardware()
    passport = TuningPassport(
        fingerprint=hardware_fingerprint(hw),
        hardware=hw,
        knobs={
            "rows_per_block": win["block"][0],
            "nnz_per_stage": win["block"][1],
            "tile": win["tile"],
            "slot_order": win["slot_order"],
            "dma": win["dma"],
            "comm_mode": win["comm_mode"],
            "fuse": fuse,
            "precision": win["precision"],
            "wire": win["wire"],
            "y_slab": win["y_slab"],
        },
        workload={
            "n": geo.n, "n_angles": geo.n_angles, "p_data": p_data,
            "n_slices": n_slices, "mem_budget": int(mem_budget),
        },
        objective={
            k: win[k]
            for k in (
                "total_seconds", "dma_issue_seconds", "hbm_seconds",
                "ici_seconds", "dci_seconds", "ici_bytes", "dci_bytes",
                "n_slabs",
            )
        } | ({"baseline": base} if base is not None else {}),
        per_copy_overhead_s=overhead,
        overhead_source=source,
    )
    return passport, trials
