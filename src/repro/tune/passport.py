"""Tuning passports: versioned, per-hardware persisted autotune results.

A passport is one JSON file per hardware fingerprint holding the knob
settings the autotuner picked and the modeled objective that picked
them.  The rules:

* **Canonical bytes.**  ``save_passport`` serializes with sorted keys,
  fixed separators and a trailing newline, and carries no timestamps or
  environment noise -- two runs of the same sweep on the same hardware
  produce *byte-identical* files (pinned by ``tests/test_tune.py``).
  Writes go through the same tmp + ``os.replace`` atomic-publish idiom
  as ``stream.store.SlabStore`` manifests: readers never observe a
  half-written passport.
* **Versioned.**  ``schema_version`` gates forward compatibility: a
  passport written by a *newer* schema raises
  :class:`PassportVersionError` on load instead of being silently
  misread.  :func:`resolve_passport` (the consumer entry point used by
  ``ReconConfig.tuned``, ``launch.recon --tune-dir``,
  ``stream.scheduler.suggest_slab`` and ``serve.admission``) demotes
  *any* unusable file -- future version, corrupt JSON, wrong shape --
  to a ``UserWarning`` plus ``None``, so a bad passport can never take
  down a job that would have run fine untuned.
* **Keyed by hardware.**  The filename embeds
  :func:`hardware_fingerprint`: sha256 over the canonical hardware
  description (backend, device kind, device count), truncated to 16 hex
  chars.  A passport tuned on one machine is invisible on another.

Doctest -- round trip, determinism, and the corrupt-file demotion:

>>> import tempfile, warnings
>>> hw = {"backend": "cpu", "device_kind": "cpu", "n_devices": 1}
>>> fp = hardware_fingerprint(hw)
>>> len(fp)
16
>>> p = TuningPassport(fingerprint=fp, hardware=hw,
...                    knobs={"dma": "coalesced", "slot_order": "runs"})
>>> d = tempfile.mkdtemp()
>>> path = save_passport(p, d)
>>> first = open(path, "rb").read()
>>> save_passport(p, d) == path and open(path, "rb").read() == first
True
>>> resolve_passport(d, fp).knobs["slot_order"]
'runs'
>>> _ = open(path, "w").write("{not json")
>>> with warnings.catch_warnings(record=True) as w:
...     warnings.simplefilter("always")
...     resolve_passport(d, fp) is None and len(w) == 1
True
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings

from ..kernels.traffic import PER_COPY_OVERHEAD_S

__all__ = [
    "SCHEMA_VERSION",
    "PassportVersionError",
    "TuningPassport",
    "describe_hardware",
    "hardware_fingerprint",
    "passport_path",
    "save_passport",
    "load_passport",
    "resolve_passport",
]

SCHEMA_VERSION = 1

# per_copy_overhead_s provenance ladder (see benchmarks.bench_spmm.
# calibrate_per_copy_overhead): "default" = the traffic-model constant,
# "measured-interpret" = micro-sweep timed under Pallas interpret mode
# (a smoke of the calibration plumbing, NOT a DMA-engine number),
# "measured" = micro-sweep timed on real hardware.
OVERHEAD_SOURCES = ("default", "measured-interpret", "measured")


class PassportVersionError(RuntimeError):
    """Passport written by a newer schema than this build understands."""


@dataclasses.dataclass(frozen=True)
class TuningPassport:
    """One hardware's tuned configuration (see module docstring).

    ``knobs`` is what consumers apply (partition + runtime settings:
    ``rows_per_block``, ``nnz_per_stage``, ``tile``, ``slot_order``,
    ``dma``, ``comm_mode``, ``fuse``, ``y_slab``); ``objective`` records
    the modeled seconds/bytes that made them win, next to the same
    numbers for the untuned default so the margin is auditable.
    """

    fingerprint: str
    hardware: dict
    knobs: dict
    schema_version: int = SCHEMA_VERSION
    workload: dict = dataclasses.field(default_factory=dict)
    objective: dict = dataclasses.field(default_factory=dict)
    per_copy_overhead_s: float = PER_COPY_OVERHEAD_S
    overhead_source: str = "default"

    def __post_init__(self):
        if self.overhead_source not in OVERHEAD_SOURCES:
            raise ValueError(
                f"overhead_source {self.overhead_source!r}; one of "
                f"{OVERHEAD_SOURCES}"
            )


def describe_hardware() -> dict:
    """Canonical description of the machine the process can see.

    Backend + device kind + count is what changes the cost-model inputs
    (and so the argmin); library versions and hostnames deliberately do
    NOT enter the fingerprint -- a pip upgrade should not orphan a
    passport.  Works without jax (pure-host CI): falls back to a
    "nojax" backend.
    """
    try:
        import jax

        devs = jax.devices()
        return {
            "backend": jax.default_backend(),
            "device_kind": devs[0].device_kind if devs else "none",
            "n_devices": len(devs),
        }
    except Exception:  # noqa: BLE001 -- no jax / no runtime: still tunable
        return {"backend": "nojax", "device_kind": "none", "n_devices": 0}


def _canonical(obj) -> bytes:
    return (
        json.dumps(obj, sort_keys=True, separators=(",", ":")) + "\n"
    ).encode()


def hardware_fingerprint(hardware: dict | None = None) -> str:
    """sha256 over the canonical hardware description, 16 hex chars."""
    if hardware is None:
        hardware = describe_hardware()
    return hashlib.sha256(_canonical(hardware)).hexdigest()[:16]


def passport_path(tune_dir: str, fingerprint: str) -> str:
    return os.path.join(tune_dir, f"passport-{fingerprint}.json")


def save_passport(passport: TuningPassport, tune_dir: str) -> str:
    """Atomically publish ``passport`` under ``tune_dir``; returns path.

    Canonical serialization (sorted keys, fixed separators, trailing
    newline, no timestamps) => byte-determinism across runs.
    """
    os.makedirs(tune_dir, exist_ok=True)
    path = passport_path(tune_dir, passport.fingerprint)
    payload = _canonical(dataclasses.asdict(passport))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)  # atomic publish, as SlabStore manifests
    return path


def load_passport(path: str) -> TuningPassport:
    """Parse one passport file; strict (raises) -- see resolve_passport.

    Raises :class:`PassportVersionError` when the file's
    ``schema_version`` is newer than this build's, ``ValueError`` /
    ``KeyError`` / ``json.JSONDecodeError`` on malformed content.
    """
    with open(path, "rb") as f:
        raw = json.loads(f.read().decode())
    if not isinstance(raw, dict):
        raise ValueError(f"passport {path}: expected a JSON object")
    ver = raw.get("schema_version")
    if not isinstance(ver, int):
        raise ValueError(f"passport {path}: missing schema_version")
    if ver > SCHEMA_VERSION:
        raise PassportVersionError(
            f"passport {path} has schema_version={ver}, newer than this "
            f"build's {SCHEMA_VERSION}; refusing to guess at its fields"
        )
    fields = {f.name for f in dataclasses.fields(TuningPassport)}
    return TuningPassport(**{k: v for k, v in raw.items() if k in fields})


def resolve_passport(
    tune_dir: str | None,
    fingerprint: str | None = None,
) -> TuningPassport | None:
    """Consumer entry point: best-effort passport lookup, never raises.

    Missing dir/file -> ``None`` silently (untuned is the normal cold
    state); unusable file (corrupt, future schema, wrong fingerprint
    inside) -> ``UserWarning`` + ``None`` so jobs degrade to defaults
    instead of dying on a bad cache.
    """
    if tune_dir is None:
        return None
    if fingerprint is None:
        fingerprint = hardware_fingerprint()
    path = passport_path(tune_dir, fingerprint)
    if not os.path.exists(path):
        return None
    try:
        p = load_passport(path)
    except Exception as e:  # noqa: BLE001 -- demote, see docstring
        warnings.warn(
            f"ignoring unusable tuning passport {path}: "
            f"{type(e).__name__}: {e}",
            UserWarning,
            stacklevel=2,
        )
        return None
    if p.fingerprint != fingerprint:
        warnings.warn(
            f"ignoring tuning passport {path}: embedded fingerprint "
            f"{p.fingerprint!r} != expected {fingerprint!r}",
            UserWarning,
            stacklevel=2,
        )
        return None
    return p
