"""Config autotuning: sweep the modeled design space, persist a passport.

``repro.tune`` closes the loop between the shared cost models
(``kernels.traffic``, ``launch.xct_perf.comm_volume``,
``stream.scheduler.suggest_slab``) and the runtime configs that consume
them.  :func:`autotune.autotune` sweeps block shape x slab budget x comm
mode x dma mode x slot order through those models -- the *modeled* tier
needs no accelerator at all -- and persists the argmin as a versioned,
per-hardware **tuning passport** (:mod:`~repro.tune.passport`) that
``core.recon.ReconConfig.tuned``, ``launch.recon --tune-dir``,
``stream.scheduler.suggest_slab(passport=...)`` and
``serve.admission.AdmissionController(tune_dir=...)`` all resolve by
hardware fingerprint.
"""
from .autotune import DEFAULT_SPACE, autotune, modeled_objective
from .passport import (
    SCHEMA_VERSION,
    PassportVersionError,
    TuningPassport,
    describe_hardware,
    hardware_fingerprint,
    load_passport,
    passport_path,
    resolve_passport,
    save_passport,
)

__all__ = [
    "DEFAULT_SPACE",
    "autotune",
    "modeled_objective",
    "SCHEMA_VERSION",
    "PassportVersionError",
    "TuningPassport",
    "describe_hardware",
    "hardware_fingerprint",
    "load_passport",
    "passport_path",
    "resolve_passport",
    "save_passport",
]
