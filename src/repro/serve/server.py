"""The in-process reconstruction service: submit jobs, drain batches.

``ReconServer`` ties the serve subsystem together around the machinery
the rest of the repo already trusts:

* **submit** fingerprints the job (``core.partition.plan_key``), prices
  it against the memory budget (``serve.admission``, allocation-free via
  ``estimate_plan``) and either queues it or rejects it with the reason.
* **step** forms one batch (``serve.batching``: priority + per-tenant
  fairness, then same-key coalescing under the budget), resolves the
  plan through the byte-bounded LRU ``serve.plan_cache`` -- the cold
  path (``build_plan`` + ``Reconstructor`` jit) runs at most once per
  resident key -- and drains the batch's slabs round-robin through one
  ``stream.scheduler.Prefetcher`` so every co-scheduled job streams
  progressive previews from its first slab on.
* Results land in per-job ``stream.SlabStore`` volumes (atomic shard
  publishes -- a preview path is always a complete, memmap-able slab),
  with per-request queue/load/upload/solve telemetry.
* The path **self-heals** (``repro.resil``): transient slab-load
  failures retry under the job's (or server's) ``RetryPolicy``, jobs
  carry optional wall-clock deadlines, and repeated plan-build failures
  trip a per-``plan_key`` circuit breaker that turns the key's jobs
  away (terminal ``rejected_circuit``) for a cooldown instead of
  re-paying the broken build.

Per-slab solves go through the same ``Reconstructor.reconstruct`` the
streaming driver uses, on independent slices, so a job's volume is
bit-exact vs running it alone through ``stream.reconstruct_streaming``
regardless of what it was batched or interleaved with (pinned by
``tests/test_serve.py``).

Synchronous use::

    srv = ReconServer(mem_budget=2 * 2**30, workdir=tmp)
    job = srv.submit(JobSpec(geo=geo, sino=sino))
    srv.drain()                      # run queued batches to completion
    vol = job.volume.to_array()      # [n_vox, Y]

Background use: ``start()`` spins a scheduler thread; ``submit`` wakes
it; ``job.wait()`` joins on completion; ``stop()`` shuts it down.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time

import numpy as np

from ..core.partition import PartitionConfig, build_plan, plan_key
from ..core.recon import ReconConfig, Reconstructor
from ..dist import Topology
from ..obs import metrics as obs_metrics
from ..obs.trace import span as obs_span
from ..resil import inject
from ..resil.circuit import CircuitBreaker
from ..resil.errors import DeadlineExceeded
from ..resil.retry import RetryPolicy, call_with_retry
from ..stream.scheduler import Prefetcher, PrefetchError
from ..stream.store import SlabStore
from .admission import AdmissionController
from .batching import fair_order, form_batch, interleave_slabs
from .jobs import Job, JobSpec
from .plan_cache import PlanCache

__all__ = ["ReconServer"]


class ReconServer:
    """Multi-tenant reconstruction-as-a-service (in-process).

    Args:
      mem_budget: bytes the running batch may occupy (resident operator
        + all co-scheduled slab working sets -- the admission formula).
      workdir: directory for per-job volume stores (``job_<id>/``);
        defaults to a fresh temp dir (kept on ``stop`` -- results live
        there).
      cache_bytes: plan-cache LRU bound (None = unbounded).
      max_batch: most jobs coalesced into one batch.
      fair_share: same-key jobs the fair-share slab sizing leaves room
        for (``admission.AdmissionController``).
      max_queue: backlog bound; submits past it are rejected.
      overlap: prefetch depth-1 staging overlap while draining slabs
        (the streaming driver's default; ``False`` degrades to a
        synchronous loop for debugging).
      on_preview: ``callable(job, SlabPreview)`` fired per published
        slab, while the job is still running.
      retry: default ``resil.RetryPolicy`` for transient slab-load
        failures (a ``JobSpec.retry`` overrides it per job; ``None``
        disables server-side load retries).
      breaker: per-``plan_key`` ``resil.CircuitBreaker`` guarding the
        plan build: after its ``threshold`` consecutive build failures
        the key's jobs come back terminal ``rejected_circuit`` until
        the cooldown lapses (default: 3 failures, 30 s cooldown).
    """

    def __init__(
        self,
        mem_budget: int,
        *,
        workdir: str | None = None,
        cache_bytes: int | None = None,
        max_batch: int = 4,
        fair_share: int = 2,
        max_queue: int | None = None,
        overlap: bool = True,
        on_preview=None,
        retry: RetryPolicy | None = RetryPolicy(),
        breaker: CircuitBreaker | None = None,
    ):
        self.workdir = workdir or tempfile.mkdtemp(prefix="repro_serve_")
        os.makedirs(self.workdir, exist_ok=True)
        self.admission = AdmissionController(
            mem_budget,
            # in-process serving solves on the default 1-device mesh:
            # meshless accounting topology => granule = fuse
            Topology.from_sizes([("model", 1, "ici")]),
            fair_share=fair_share,
            max_queue=max_queue,
        )
        self.cache = PlanCache(capacity_bytes=cache_bytes)
        self.max_batch = int(max_batch)
        self.overlap = bool(overlap)
        self.retry = retry
        self.breaker = breaker if breaker is not None else CircuitBreaker(
            threshold=3, cooldown_s=30.0
        )
        self._on_preview = on_preview
        self._lock = threading.Lock()
        self._queue: list[Job] = []
        self._jobs: dict[int, Job] = {}
        self._costs: dict[int, object] = {}  # job id -> JobCost
        self.served: dict[str, float] = {}  # tenant -> slices solved
        self.batches: list[dict] = []  # {"key", "jobs", "cold"}
        self._next_id = 0
        self._rejected = 0
        self._rejected_circuit = 0
        self._completed = 0
        self._failed = 0
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._wake = threading.Event()

    # ------------------------------------------------------------------ #
    # intake
    # ------------------------------------------------------------------ #
    def submit(self, spec: JobSpec) -> Job:
        """Price + enqueue one job; returns it (possibly ``rejected``).

        Rejection is an admission decision, not an exception: the job
        comes back terminal with ``status == "rejected"`` and the
        pricing error in ``job.error``, so a tenant script can react
        without try/except around every submit.
        """
        pcfg = spec.pcfg if spec.pcfg is not None else PartitionConfig()
        rcfg = spec.rcfg if spec.rcfg is not None else ReconConfig()
        spec = dataclasses.replace(spec, pcfg=pcfg, rcfg=rcfg)
        key = plan_key(spec.geo, pcfg, recon=rcfg)
        with self._lock:
            job = Job(self._next_id, spec, key,
                      on_preview=self._on_preview)
            self._next_id += 1
            self._jobs[job.id] = job

        rows = (
            spec.sino.rows if hasattr(spec.sino, "rows")
            else np.asarray(spec.sino).shape[0]
        )
        if rows != spec.geo.n_rays:
            job._transition(
                "rejected",
                error=f"sinogram has {rows} rays, geometry wants "
                      f"{spec.geo.n_rays}",
            )
            self._rejected += 1
            obs_metrics.inc("serve_jobs_total", status="rejected")
            return job
        try:
            # price against the real plan when one is already cached
            # (peek: pricing must not count as a serving hit)
            if spec.n_slices % rcfg.fuse:
                raise ValueError(
                    f"n_slices={spec.n_slices} not a multiple of the "
                    f"solve granule fuse={rcfg.fuse}"
                )
            entry = self.cache.peek(key)
            cost = self.admission.price(
                spec.geo, pcfg, rcfg, spec.n_slices,
                y_slab=spec.y_slab,
                plan=entry.plan if entry is not None else None,
            )
        except ValueError as e:
            job._transition("rejected", error=str(e))
            self._rejected += 1
            obs_metrics.inc("serve_jobs_total", status="rejected")
            return job
        with self._lock:
            if self.admission.queue_full(len(self._queue)):
                job._transition(
                    "rejected",
                    error=f"queue full ({len(self._queue)} >= "
                          f"{self.admission.max_queue})",
                )
                self._rejected += 1
                obs_metrics.inc("serve_jobs_total", status="rejected")
                return job
            self._costs[job.id] = cost
            self._queue.append(job)
            obs_metrics.set_gauge("serve_queue_depth", len(self._queue))
        self._wake.set()
        return job

    def job(self, job_id: int) -> Job:
        return self._jobs[job_id]

    # ------------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """Form and run one batch; returns how many jobs it drained."""
        with self._lock:
            if not self._queue:
                return 0
            ordered = fair_order(self._queue, self.served)
            batch = form_batch(
                ordered, self._costs, self.admission, self.max_batch
            )
            for job in batch:
                self._queue.remove(job)
            obs_metrics.set_gauge("serve_queue_depth", len(self._queue))
        if not batch:
            return 0
        self._run_batch(batch)
        return len(batch)

    def drain(self) -> int:
        """Run batches until the queue is empty; returns jobs drained."""
        n = 0
        while True:
            k = self.step()
            if not k:
                return n
            n += k

    def _run_batch(self, batch: list[Job]):
        key = batch[0].plan_key
        if not self.breaker.allow(key):
            # the key's build path is poisoned and cooling down: turn
            # the batch away instantly instead of re-paying the failure
            for job in batch:
                self._reject_circuit(job, key)
            return
        for job in batch:  # queue wait ends when the batch is picked
            job._transition("running")
            job.telemetry.queue_s = time.perf_counter() - job.submit_t
        try:
            entry, hit = self.cache.get_or_build(
                key, lambda: self._build(batch[0])
            )
        except Exception as e:  # noqa: BLE001 - build failure
            self.breaker.record_failure(key)
            for job in batch:
                self._fail(
                    job, f"plan build failed: {type(e).__name__}: {e}",
                    exc=e,
                )
            return
        self.breaker.record_success(key)
        self.batches.append(
            {"key": key, "jobs": [j.id for j in batch], "cold": not hit}
        )
        for job in batch:
            job.telemetry.plan_cold = not hit
        self.cache.pin(key)
        try:
            self._execute(entry, batch)
        finally:
            self.cache.unpin(key)

    def _reject_circuit(self, job: Job, key: str):
        job.telemetry.total_s = time.perf_counter() - job.submit_t
        job._transition(
            "rejected_circuit",
            error=f"plan {key[:16]} build circuit open "
                  f"(cooling down after repeated build failures)",
        )
        self._rejected_circuit += 1
        obs_metrics.inc("serve_jobs_total", status="rejected_circuit")

    def _build(self, job: Job):
        """The cold path: partition + winseg tables + solver (compiles
        lazily on first solve, memoized in ``Reconstructor._fns``)."""
        spec = job.spec
        inject.fire("serve/build")  # chaos hook: plan-build failure
        plan = build_plan(spec.geo, spec.pcfg)
        rec = Reconstructor(plan, cfg=spec.rcfg)
        vb = rec.policy.vals_bytes  # packed value width (1 on q8/fp8)
        nbytes = (
            plan.proj.hbm_bytes(value_bytes=vb)
            + plan.back.hbm_bytes(value_bytes=vb)
        )
        return plan, rec, nbytes

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def _execute(self, entry, batch: list[Job]):
        rec = entry.rec
        per_job_slabs = []
        pending: dict[int, int] = {}
        for job in batch:
            cost = self._costs[job.id]
            job.y_slab = cost.y_slab
            job.volume = SlabStore.create(
                os.path.join(self.workdir, f"job_{job.id:05d}"),
                rows=job.spec.geo.n_vox,
                n_slices=job.spec.n_slices,
                slab=cost.y_slab,
                dtype=np.float32,
            )
            job.resnorms = np.zeros(
                (job.spec.iters, job.spec.n_slices), np.float32
            )
            slabs = job.volume.slabs()
            pending[job.id] = len(slabs)
            per_job_slabs.append(slabs)

        # round-robin across jobs: every co-scheduled job sees its
        # first preview after ~one slab time
        tasks = [
            (batch[ji], rng)
            for ji, rng in interleave_slabs(per_job_slabs)
        ]

        def fetch(task):
            job, (j0, j1) = task
            policy = job.spec.retry if job.spec.retry is not None \
                else self.retry
            if policy is None:
                return job.spec.read_slab(j0, j1)

            def load(attempt):
                with obs_span(
                    "serve/load", job=job.id, j0=j0, retry=attempt
                ):
                    return job.spec.read_slab(j0, j1)

            def note():
                job.telemetry.retries += 1

            # per-job policy: a flaky tenant store retries with its own
            # backoff before the failure can surface as a PrefetchError
            return call_with_retry(
                load, policy=policy, site="serve/load", key=j0,
                on_retry=note,
            )

        while tasks:
            pre = Prefetcher(
                fetch, tasks, depth=1, enabled=self.overlap,
                stage=rec.stage_sino,
            )
            consumed = 0
            try:
                for pos, (task, staged) in enumerate(pre):
                    job, (j0, j1) = task
                    if job.status != "running":
                        # failed earlier in this drain (deadline / bad
                        # load); its later slabs are already in flight
                        consumed = pos + 1
                        continue
                    dl = job.spec.deadline_s
                    if dl is not None and (
                        time.perf_counter() - job.submit_t > dl
                    ):
                        self._fail(
                            job,
                            f"deadline {dl:g}s exceeded",
                            exc=DeadlineExceeded(f"{dl:g}s"),
                        )
                        consumed = pos + 1
                        continue
                    lane = f"tenant:{job.spec.tenant}"
                    # a solve/write failure propagates through these
                    # spans, so the failing slab's span records the
                    # exception type before _fail() sees it
                    with obs_span(
                        "serve/slab", lane=lane, job=job.id, j0=j0
                    ):
                        with obs_span(
                            "serve/solve", lane=lane, job=job.id
                        ) as sp_solve:
                            x, r = rec.reconstruct(
                                staged, iters=job.spec.iters
                            )
                        path = job.volume.write(j0, np.asarray(x))
                    job.resnorms[:, j0:j1] = r
                    tm = pre.times.get(pos, {})
                    job.telemetry.load_s += tm.get("load", 0.0)
                    job.telemetry.upload_s += tm.get("stage", 0.0)
                    job.telemetry.solve_s += sp_solve.duration_s
                    job.publish_preview(j0, j1, path)
                    with self._lock:
                        self.served[job.spec.tenant] = (
                            self.served.get(job.spec.tenant, 0.0)
                            + (j1 - j0)
                        )
                    pending[job.id] -= 1
                    if pending[job.id] == 0:
                        job.telemetry.total_s = (
                            time.perf_counter() - job.submit_t
                        )
                        job._transition("done")
                        self._completed += 1
                        obs_metrics.inc(
                            "serve_jobs_total", status="done"
                        )
                    consumed = pos + 1
            except PrefetchError as e:
                # the failing fetch/stage names its job; everything
                # already yielded for other jobs is safely on disk
                bad, _ = e.item
                self._fail(bad, f"slab load failed: {e}", exc=e.cause)
                tasks = [
                    t for t in tasks[e.index + 1:]
                    if t[0].status == "running"
                ]
                continue
            except Exception as e:  # noqa: BLE001 - solve/write failure
                bad = tasks[consumed][0]
                self._fail(bad, f"{type(e).__name__}: {e}", exc=e)
                tasks = [
                    t for t in tasks[consumed + 1:]
                    if t[0].status == "running"
                ]
                continue
            break

    def _fail(self, job: Job, msg: str, exc: BaseException | None = None):
        # a failed job still reports terminal-phase timing: total_s
        # covers submit -> failure, and the slab split it accumulated
        # before dying stays (the telemetry gap the obs PR closed)
        job.telemetry.total_s = time.perf_counter() - job.submit_t
        if exc is not None:
            job.telemetry.error_type = type(exc).__name__
        job._transition("failed", error=msg)
        self._failed += 1
        obs_metrics.inc("serve_jobs_total", status="failed")

    # ------------------------------------------------------------------ #
    # background mode
    # ------------------------------------------------------------------ #
    def start(self):
        """Run the scheduler on a daemon thread; ``submit`` wakes it."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._loop, name="repro-serve", daemon=True
        )
        self._thread.start()

    def _loop(self):
        while not self._stop_evt.is_set():
            if not self.step():
                self._wake.wait(0.05)
                self._wake.clear()

    def stop(self, drain: bool = True):
        """Stop the scheduler thread (after ``drain``-ing by default).

        Job volumes stay on disk under ``workdir`` -- results outlive
        the server.
        """
        if self._thread is None:
            return
        if drain:
            while True:
                with self._lock:
                    empty = not self._queue
                if empty:
                    break
                time.sleep(0.01)
        self._stop_evt.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    # ------------------------------------------------------------------ #
    # observability
    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(
            submitted=self._next_id,
            rejected=self._rejected,
            rejected_circuit=self._rejected_circuit,
            completed=self._completed,
            failed=self._failed,
            queued=len(self._queue),
            batches=len(self.batches),
            hit_rate=self.cache.hit_rate,
        )
        return s

    def metrics_text(self) -> str:
        """Prometheus text snapshot of the process metrics registry.

        Refreshes the point-in-time gauges first so a scrape is
        self-consistent; counters (``serve_jobs_total{status=}``,
        ``plan_cache_*_total``, ``comm_bytes_total{link=}``, ...)
        accumulate as the wired paths bump them.  The exposition is
        byte-deterministic for a given registry state (sorted series;
        see ``repro.obs.metrics``).
        """
        with self._lock:
            obs_metrics.set_gauge("serve_queue_depth", len(self._queue))
        return obs_metrics.render_prometheus()
