"""Batch formation: fairness-ordered coalescing of same-plan jobs.

Pure functions over the server's queue (no I/O, no jax) so the
scheduling policy is unit-testable in isolation:

``fair_order``
    Priority first, then *least-served tenant* first (work-proportional
    fairness: ``served`` carries slices already solved per tenant, so a
    tenant that just drained a big volume yields to the others), FIFO
    within ties.  A single greedy tenant flooding the queue cannot
    starve anyone at equal priority.

``form_batch``
    Take the head of the fair order, then coalesce every queued job
    sharing its ``plan_key`` -- in fair order, regardless of tenant:
    coalescing is free capacity, the fairness cost was already paid by
    head selection -- while the admission budget holds
    (``AdmissionController.fits``: one shared operator + the sum of
    slab working sets) and the batch stays under ``max_batch``.

``interleave_slabs``
    Round-robin the batch's slabs across jobs, so every co-scheduled
    job streams its first preview after ~one slab time instead of
    waiting its turn behind a whole earlier volume -- the progressive-
    results half of the iFDK "instant reconstruction" framing.

>>> order = interleave_slabs([[(0, 4), (4, 8)], [(0, 2)]])
>>> [(j, s) for j, s in order]
[(0, (0, 4)), (1, (0, 2)), (0, (4, 8))]
"""
from __future__ import annotations

__all__ = ["fair_order", "form_batch", "interleave_slabs"]


def fair_order(jobs, served: dict) -> list:
    """Queued jobs in scheduling order (see module docstring).

    ``served`` maps tenant -> slices already solved; missing tenants
    count as 0 (a brand-new tenant is maximally under-served).
    """
    return sorted(
        jobs,
        key=lambda j: (
            -j.spec.priority,
            float(served.get(j.spec.tenant, 0.0)),
            j.id,
        ),
    )


def form_batch(ordered, costs: dict, admission, max_batch: int) -> list:
    """The next batch: head + same-key followers that fit the budget.

    Args:
      ordered: queued jobs, already through :func:`fair_order`.
      costs: job id -> ``admission.JobCost`` (priced at submit).
      admission: ``AdmissionController`` (the ``fits`` oracle).
      max_batch: hard cap on co-scheduled jobs.
    """
    if not ordered:
        return []
    head = ordered[0]
    batch = [head]
    batch_costs = [costs[head.id]]
    for job in ordered[1:]:
        if len(batch) >= max_batch:
            break
        if job.plan_key != head.plan_key:
            continue
        trial = batch_costs + [costs[job.id]]
        if not admission.fits(trial):
            continue  # stays queued; re-tried next batch
        batch.append(job)
        batch_costs = trial
    return batch


def interleave_slabs(per_job_slabs) -> list:
    """Round-robin ``[(job_index, (j0, j1)), ...]`` across jobs."""
    out = []
    depth = max((len(s) for s in per_job_slabs), default=0)
    for d in range(depth):
        for ji, slabs in enumerate(per_job_slabs):
            if d < len(slabs):
                out.append((ji, slabs[d]))
    return out
