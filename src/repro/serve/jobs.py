"""Job model of the reconstruction service: specs, lifecycle, telemetry.

A *job* is one reconstruction request: a sinogram (numpy array or an
on-disk :class:`~repro.stream.store.SlabStore`), the scan geometry and
solver configuration that shape its compiled plan, and multi-tenant
metadata (tenant, priority).  The server prices it at submit
(``serve.admission``), queues it, batches it with same-``plan_key``
neighbors (``serve.batching``) and drains it slab by slab -- publishing
a :class:`SlabPreview` per completed slab *while the job is still
running* (iFDK's "instant reconstruction": the beamline user watches
slabs land instead of waiting for the volume).

Lifecycle (monotone; terminal states starred)::

    QUEUED -> RUNNING -> DONE*
       \\-> REJECTED*         (admission: impossible budget / full queue)
        \\-> REJECTED_CIRCUIT* (plan build circuit open, see resil)
         \\-> FAILED*          (runtime error; other jobs keep draining)

Jobs carry their own resilience knobs: ``JobSpec.retry`` (a
``resil.RetryPolicy`` for transient slab-load failures; ``None`` uses
the server default) and ``JobSpec.deadline_s`` (wall-clock budget from
submit -- a job past it fails with ``error_type="DeadlineExceeded"``
instead of starving its batch mates).

Telemetry per job aggregates the same load/upload/solve split the
streaming driver records per slab (``stream.StreamResult``), plus the
service-level numbers the benchmarks gate: queue wait and
queue-to-first-slab (``bench_serve``'s p50/p95 metric).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

__all__ = ["JobSpec", "Job", "JobTelemetry", "SlabPreview", "STATUSES"]

STATUSES = (
    "queued", "running", "done", "rejected", "rejected_circuit", "failed",
)
_TERMINAL = ("done", "rejected", "rejected_circuit", "failed")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """What a tenant submits.

    ``sino`` is either a ``[n_rays, Y]`` numpy array or a
    ``stream.SlabStore`` holding one; ``y_slab=None`` lets admission
    size the slab from the server's memory budget (fair-share, see
    ``serve.admission.AdmissionController.price``).
    """

    geo: object  # core.geometry.XCTGeometry
    sino: object  # np.ndarray | stream.SlabStore
    pcfg: object = None  # core.partition.PartitionConfig (None = default)
    rcfg: object = None  # core.recon.ReconConfig (None = default)
    iters: int = 30
    tenant: str = "default"
    priority: int = 0  # higher runs earlier
    y_slab: int | None = None  # None -> sized by admission
    retry: object = None  # resil.RetryPolicy | None (server default)
    deadline_s: float | None = None  # wall budget from submit

    @property
    def n_slices(self) -> int:
        return int(
            self.sino.n_slices
            if hasattr(self.sino, "n_slices")
            else np.asarray(self.sino).shape[1]
        )

    def read_slab(self, j0: int, j1: int):
        """One sinogram slab, whatever the backing storage."""
        if hasattr(self.sino, "read"):
            return self.sino.read(j0, j1)
        return np.asarray(self.sino)[:, j0:j1]


@dataclasses.dataclass
class JobTelemetry:
    """Per-request split, aggregated over the job's slabs.

    ``queue_s`` is submit -> first slab *starts*; ``first_slab_s`` is
    submit -> first slab *published* (the queue-to-first-slab the
    warm-path acceptance compares: a cache hit skips the plan build, so
    a warm job's number is strictly below the cold job's).  The
    load/upload/solve sums mirror the ``stream.StreamResult`` per-slab
    fields.  Timing fields follow the repo-wide ``*_s`` convention
    (seconds, float).

    A FAILED job still carries telemetry up to the failure point:
    whatever slabs completed keep their split, ``total_s`` covers
    submit -> failure, and ``error_type`` names the exception class
    (the failing ``serve/slab`` span records the same under its
    ``exception`` attr).
    """

    queue_s: float = 0.0
    first_slab_s: float = 0.0
    total_s: float = 0.0
    load_s: float = 0.0
    upload_s: float = 0.0
    solve_s: float = 0.0
    n_slabs: int = 0
    plan_cold: bool = False  # this job paid the plan build
    error_type: str | None = None  # exception class name (failed jobs)
    retries: int = 0  # transient slab-load retries this job absorbed


@dataclasses.dataclass(frozen=True)
class SlabPreview:
    """One progressively published slab (the store shard IS the data).

    ``path`` points at the atomically published ``SlabStore`` shard, so
    a client can memmap the preview without copying; ``seconds`` is wall
    time since submit (monotone within a job -- previews stream in
    order while the job is still running).
    """

    job_id: int
    j0: int
    j1: int
    path: str
    seconds: float  # since submit


class Job:
    """A submitted job: spec + mutable status/results/telemetry.

    Thread-safe where it matters for a service: status transitions and
    preview appends happen under a lock, and ``wait()`` blocks on an
    event set at any terminal state (the background-server mode's join
    point).  Previews are also delivered to the spec-independent
    ``on_preview`` callback *before* the job completes -- pinned by the
    serve-smoke CI job.
    """

    def __init__(self, job_id: int, spec: JobSpec, key: str,
                 on_preview=None):
        self.id = job_id
        self.spec = spec
        self.plan_key = key
        self.status = "queued"
        self.error: str | None = None
        self.y_slab: int | None = spec.y_slab
        self.volume = None  # stream.SlabStore once running
        self.resnorms: np.ndarray | None = None
        self.previews: list[SlabPreview] = []
        self.telemetry = JobTelemetry()
        self.submit_t = time.perf_counter()
        self._on_preview = on_preview
        self._lock = threading.Lock()
        self._done = threading.Event()

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    @property
    def terminal(self) -> bool:
        return self.status in _TERMINAL

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the job reaches a terminal state."""
        return self._done.wait(timeout)

    def _transition(self, status: str, error: str | None = None):
        assert status in STATUSES, status
        with self._lock:
            if self.terminal:  # terminal states are sticky
                return
            self.status = status
            if error is not None:
                self.error = error
        if status in _TERMINAL:
            self._done.set()

    def publish_preview(self, j0: int, j1: int, path: str):
        """Record (and stream out) one completed slab."""
        now = time.perf_counter() - self.submit_t
        pv = SlabPreview(self.id, j0, j1, path, now)
        with self._lock:
            self.previews.append(pv)
            if self.telemetry.n_slabs == 0:
                self.telemetry.first_slab_s = now
            self.telemetry.n_slabs += 1
        if self._on_preview is not None:
            self._on_preview(self, pv)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Job(id={self.id}, tenant={self.spec.tenant!r}, "
            f"key={self.plan_key}, status={self.status})"
        )
