"""Plan cache: amortize the cold path (partition + winseg + compile).

The expensive part of serving a reconstruction job is not the solve --
it is everything keyed by the geometry/config fingerprint
(``core.partition.plan_key``): tracing the Siddon system matrix,
compiling it into blocked-ELL shards + winseg DMA tables
(``build_plan``), building the exchange tables, and jit-compiling the
CG step.  All of that is *identical* for every job that shares a key
(parallel-beam slices share ``A``; same block shape + dtype ladder +
comm/dma mode means the same kernel), so the service builds it once and
hits the cache for the rest of the traffic -- the warm path's
queue-to-first-slab is strictly below the cold path's (pinned by
``tests/test_serve.py``).

The LRU bound is in *bytes*, not entries, priced with the same
accounting every other layer uses: ``OperatorShards.hbm_bytes`` at the
precision policy's storage width for both operators (the traffic
model's resident-operator term -- exactly what ``suggest_slab`` calls
``fixed``).  Entries pinned by a running batch are never evicted.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Callable

from ..obs import metrics as obs_metrics

__all__ = ["PlanCache", "PlanEntry"]


@dataclasses.dataclass
class PlanEntry:
    """One cached cold path: the plan and its mesh-bound solver.

    ``rec`` (a ``core.recon.Reconstructor``) carries the jitted CG
    functions in its ``_fns`` memo, so a cache hit reuses the compile
    too, not just the partition.  ``bytes`` is the resident operator
    footprint that counts against the cache budget; ``build_seconds``
    is what the hit saved (reported by ``bench_serve``).
    """

    key: str
    plan: object  # core.partition.Plan
    rec: object  # core.recon.Reconstructor
    bytes: int
    build_seconds: float
    pinned: int = 0  # running batches holding this entry


class PlanCache:
    """Byte-bounded LRU over :class:`PlanEntry`, with hit/miss counters.

    ``get_or_build(key, build)`` is the only path in: ``build()`` runs
    at most once per resident key (under the lock -- a second tenant
    asking for the same geometry while the first build runs would
    otherwise duplicate the most expensive operation in the service).
    Counters are the observable the acceptance criteria assert against:
    a warm job must show ``builds`` unchanged.
    """

    def __init__(self, capacity_bytes: int | None = None):
        self.capacity_bytes = capacity_bytes
        self._entries: OrderedDict[str, PlanEntry] = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0

    # ------------------------------------------------------------------ #
    # interrogation
    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    @property
    def bytes(self) -> int:
        return sum(e.bytes for e in self._entries.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
            "entries": len(self._entries),
            "bytes": self.bytes,
        }

    def peek(self, key: str) -> PlanEntry | None:
        """Look without touching: no counters, no LRU reorder.

        Admission pricing uses this to price against the *real* cached
        plan when one exists -- a pricing peek must not masquerade as a
        serving hit in the counters the acceptance tests assert on.
        """
        with self._lock:
            return self._entries.get(key)

    # ------------------------------------------------------------------ #
    # the one path in
    # ------------------------------------------------------------------ #
    def get_or_build(
        self, key: str, build: Callable[[], tuple]
    ) -> tuple[PlanEntry, bool]:
        """Return ``(entry, hit)``; ``build()`` -> ``(plan, rec, bytes)``.

        On a miss the new entry is admitted even if it alone exceeds
        the capacity (the service already admission-checked the job;
        a cache too small for one plan should degrade to rebuild-every-
        time, not refuse service) -- everything evictable is evicted
        first.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
                obs_metrics.inc("plan_cache_hits_total")
                self._entries.move_to_end(key)  # LRU touch
                return entry, True
            self.misses += 1
            obs_metrics.inc("plan_cache_misses_total")
            t0 = time.perf_counter()
            plan, rec, nbytes = build()
            self.builds += 1
            entry = PlanEntry(
                key=key, plan=plan, rec=rec, bytes=int(nbytes),
                build_seconds=time.perf_counter() - t0,
            )
            self._entries[key] = entry
            self._evict_to_fit()
            return entry, False

    def pin(self, key: str):
        """Mark an entry in use by a running batch (eviction-proof)."""
        with self._lock:
            self._entries[key].pinned += 1

    def unpin(self, key: str):
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e.pinned > 0:
                e.pinned -= 1
                self._evict_to_fit()  # a deferred eviction may now land

    def _evict_to_fit(self):
        """Drop LRU unpinned entries until the byte budget holds.

        The entry just touched/inserted sits at the MRU end, so it is
        the last candidate -- a one-entry cache always keeps the key
        the current batch needs.
        """
        if self.capacity_bytes is None:
            return
        while self.bytes > self.capacity_bytes:
            mru = next(reversed(self._entries))
            victim = next(
                (
                    k
                    for k, e in self._entries.items()  # LRU -> MRU
                    if e.pinned == 0 and k != mru
                ),
                None,
            )
            if victim is None:  # only pinned entries / the MRU one left
                return
            self._entries.pop(victim)
            self.evictions += 1
            obs_metrics.inc("plan_cache_evictions_total")
