"""Admission control: price every job before it touches the machine.

The service's memory budget ``M`` has to cover, at any instant, the
resident operator of the running batch plus every co-scheduled job's
in-flight slab working set.  Both terms come from the same accounting
the rest of the stack already trusts -- ``stream.scheduler.suggest_slab``
(which itself prices the operator with ``OperatorShards.hbm_bytes`` and
the slab traffic with ``kernels.traffic.spmm_traffic``) -- evaluated on
an **allocation-free** ``estimate_plan`` abstraction, so pricing a job
never pays the cold path it is deciding about:

    admit(batch) <=> fixed + sum_j y_slab_j * per_slice  <=  M

``fixed`` is shared across a batch (same ``plan_key`` => same resident
operator -- that is what batching is for); each job contributes only
its slab term.  A job whose single solve granule cannot fit alongside
the operator is *rejected* outright (``suggest_slab`` raises); a job
that fits alone but not alongside the running work is *queued* -- the
batching scheduler re-tries it when slots free up.

Fair-share sizing: with ``fair_share = s``, an unsized job
(``y_slab=None``) gets ``(M - fixed) / s`` of the working budget, so
``s`` same-key jobs can always be co-scheduled.  Meshless doctest (the
same estimate/Topology machinery the slab-size formula's doctest uses,
so this works at full dataset scale):

>>> from repro.core.geometry import XCTGeometry
>>> from repro.core.partition import PartitionConfig
>>> from repro.core.recon import ReconConfig
>>> from repro.dist import Topology
>>> adm = AdmissionController(
...     mem_budget=4 * 2**30,
...     topology=Topology.from_sizes([("model", 16, "ici")]),
...     fair_share=2)
>>> geo = XCTGeometry(n=512, n_angles=256)
>>> pcfg = PartitionConfig(n_data=16, tile=32, rows_per_block=64,
...                        nnz_per_stage=64)
>>> cost = adm.price(geo, pcfg, ReconConfig(precision="mixed", fuse=16),
...                  n_slices=4096)
>>> cost.slab_bytes <= 4 * 2**30          # one job fits its share
True
>>> adm.fits([cost, cost])                # two fair shares co-schedule
True
>>> adm.fits([cost] * 3)                  # a third would blow M
False
>>> try:                                  # explicit oversize: rejected
...     adm.price(geo, pcfg, ReconConfig(precision="mixed", fuse=16),
...               n_slices=4096, y_slab=4096)
... except ValueError as e:
...     print(str(e).split(":")[0])
y_slab=4096 overflows the budget
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["JobCost", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class JobCost:
    """The priced footprint of one job (see module docstring)."""

    fixed_bytes: int  # resident operator, shared per plan_key
    per_slice_bytes: int  # slab working set per slice
    y_slab: int  # slices per in-flight slab
    n_slices: int  # job volume

    @property
    def working_bytes(self) -> int:
        """The job's own in-flight term."""
        return self.y_slab * self.per_slice_bytes

    @property
    def slab_bytes(self) -> int:
        """Peak bytes if this job ran alone."""
        return self.fixed_bytes + self.working_bytes

    @property
    def n_slabs(self) -> int:
        return int(math.ceil(self.n_slices / self.y_slab))


class AdmissionController:
    """Price jobs against a byte budget; decide admit/queue/reject.

    ``fair_share`` is how many same-key jobs the sizing leaves room
    for; ``max_queue`` bounds the backlog (a submit past it is rejected
    -- backpressure, not unbounded latency).  A tuning passport
    (``repro.tune``; pass one explicitly or a ``tune_dir`` to resolve
    this machine's by hardware fingerprint) flows into every
    ``suggest_slab`` pricing call, so admission and the streaming
    scheduler size slabs from the SAME tuned cap.
    """

    def __init__(
        self,
        mem_budget: int,
        topology,
        *,
        fair_share: int = 2,
        max_queue: int | None = None,
        passport=None,
        tune_dir: str | None = None,
    ):
        if fair_share < 1:
            raise ValueError(f"fair_share must be >= 1: {fair_share}")
        self.mem_budget = int(mem_budget)
        self.topology = topology
        self.fair_share = int(fair_share)
        self.max_queue = max_queue
        if passport is None and tune_dir is not None:
            from ..tune.passport import resolve_passport

            passport = resolve_passport(tune_dir)
        self.passport = passport

    # ------------------------------------------------------------------ #
    # pricing
    # ------------------------------------------------------------------ #
    def price(
        self,
        geo,
        pcfg,
        rcfg,
        n_slices: int,
        *,
        y_slab: int | None = None,
        plan=None,
    ) -> JobCost:
        """Price one job; raises ``ValueError`` when it can never fit.

        ``plan`` may pass a real (cached) partition plan to price exact
        shard shapes; the default prices an ``estimate_plan``
        abstraction -- allocation-free, so admission never builds what
        it might reject.
        """
        from ..core.partition import estimate_plan
        from ..stream.scheduler import suggest_slab

        if plan is None:
            plan = estimate_plan(geo, pcfg)
        # suggest_slab raises ValueError when operator + one granule
        # overflow the budget: that is the reject signal
        sp = suggest_slab(
            plan, rcfg, self.topology, self.mem_budget,
            n_slices=n_slices, passport=self.passport,
        )
        if y_slab is None:
            # fair share: leave room for fair_share - 1 peers
            share = (self.mem_budget - sp.fixed_bytes) // self.fair_share
            y_fair = (
                share // sp.per_slice_bytes // sp.granule * sp.granule
            )
            y_slab = max(sp.granule, min(sp.y_slab, y_fair))
            y_slab = min(
                y_slab, max(sp.granule, n_slices // sp.granule
                            * sp.granule),
            )
        else:
            y_slab = int(y_slab)
            if y_slab % sp.granule:
                raise ValueError(
                    f"y_slab {y_slab} not a multiple of the solve "
                    f"granule {sp.granule}"
                )
            if sp.fixed_bytes + y_slab * sp.per_slice_bytes \
                    > self.mem_budget:
                raise ValueError(
                    f"y_slab={y_slab} overflows the budget: "
                    f"{sp.fixed_bytes} operator + {y_slab} x "
                    f"{sp.per_slice_bytes} working > {self.mem_budget}"
                )
        return JobCost(
            fixed_bytes=sp.fixed_bytes,
            per_slice_bytes=sp.per_slice_bytes,
            y_slab=int(y_slab),
            n_slices=int(n_slices),
        )

    # ------------------------------------------------------------------ #
    # decisions
    # ------------------------------------------------------------------ #
    def fits(self, costs) -> bool:
        """Can these same-key jobs run concurrently under the budget?

        The operator term is shared (max, not sum -- one plan resident);
        each job adds only its slab working set.
        """
        costs = list(costs)
        if not costs:
            return True
        fixed = max(c.fixed_bytes for c in costs)
        working = sum(c.working_bytes for c in costs)
        return fixed + working <= self.mem_budget

    def queue_full(self, backlog: int) -> bool:
        return self.max_queue is not None and backlog >= self.max_queue
