"""repro.serve -- multi-tenant reconstruction-as-a-service.

Serving many reconstruction requests on one machine is dominated by the
cold path: partitioning the Siddon operator into blocked-ELL shards +
winseg DMA tables and jit-compiling the CG solver.  Parallel-beam
slices share one system matrix, so every job with the same
geometry/config fingerprint (``core.partition.plan_key``) can reuse all
of it.  This package builds the service around that observation:

``jobs``        -- :class:`JobSpec` / :class:`Job` lifecycle, per-slab
                   :class:`SlabPreview` streaming, per-request telemetry
``plan_cache``  -- byte-bounded LRU over built plans + solvers
``admission``   -- price-before-admit against the memory budget
                   (``suggest_slab`` on allocation-free estimates)
``batching``    -- fairness ordering, same-key coalescing, slab
                   round-robin interleave
``server``      -- :class:`ReconServer`: submit / step / drain, optional
                   background scheduler thread

See ``docs/architecture.md`` ("Reconstruction-as-a-service") for the
module map and the admission-control formula.
"""
from .admission import AdmissionController, JobCost
from .batching import fair_order, form_batch, interleave_slabs
from .jobs import STATUSES, Job, JobSpec, JobTelemetry, SlabPreview
from .plan_cache import PlanCache, PlanEntry
from .server import ReconServer

__all__ = [
    "AdmissionController",
    "JobCost",
    "fair_order",
    "form_batch",
    "interleave_slabs",
    "STATUSES",
    "Job",
    "JobSpec",
    "JobTelemetry",
    "SlabPreview",
    "PlanCache",
    "PlanEntry",
    "ReconServer",
]
