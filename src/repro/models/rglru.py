"""RG-LRU recurrent block (recurrentgemma / Griffin).

Real-Gated Linear Recurrent Unit:

  r_t = sigmoid(x_t W_a + b_a)              (recurrence gate)
  i_t = sigmoid(x_t W_x + b_x)              (input gate)
  a_t = exp(c * softplus(Lambda) * (-r_t))  (per-channel decay, c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` (the recurrence is a linear
scan h_t = a_t h_{t-1} + b_t, O(log T) depth -- the TPU-friendly form);
decode is a single step.  The surrounding block follows Griffin: dual
branches (gate via GeLU, recurrent via conv1d -> RG-LRU), merged and
projected out.  Temporal conv1d keeps a (width-1)-token state for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

C_FACTOR = 8.0


def rglru_init(key, cfg):
    d = cfg.d_model
    r = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    return {
        "wx": dense_init(ks[0], (d, r)),  # input branch
        "wy": dense_init(ks[1], (d, r)),  # gate branch
        "conv": dense_init(ks[2], (cfg.conv_width, r)) * 0.1,
        "wa": dense_init(ks[3], (r, r)),
        "ba": jnp.zeros((r,), jnp.float32),
        "wi": dense_init(ks[4], (r, r)),
        "bi": jnp.zeros((r,), jnp.float32),
        # softplus(lam) in ~U[...] so decay a^c spans useful range
        "lam": jnp.linspace(0.5, 4.0, r, dtype=jnp.float32),
        "wo": dense_init(ks[5], (r, d)),
    }


def _conv1d(x, w, state=None):
    """Causal depthwise conv over time; x [B,T,R], w [W,R].

    Returns (y, new_state [B, W-1, R]) -- state carries the last W-1 inputs
    for streaming decode.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, T+W-1, R]
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
        for i in range(width)
    )
    new_state = xp[:, xp.shape[1] - (width - 1) :]
    return y, new_state


def _lru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan; a,b [B,T,R]."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def op(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def rglru_apply(p, x, *, cfg, cache=None, mode="train"):
    """Returns (y, new_cache); cache = {"h": [B,R], "conv": [B,W-1,R]}."""
    adt = x.dtype
    bsz = x.shape[0]
    r = p["lam"].shape[0]

    gate = jax.nn.gelu(x @ p["wy"].astype(adt))
    u = x @ p["wx"].astype(adt)
    u, conv_state = _conv1d(
        u, p["conv"], None if cache is None else cache["conv"]
    )

    uf = u.astype(jnp.float32)
    rgate = jax.nn.sigmoid(uf @ p["wa"] + p["ba"])
    igate = jax.nn.sigmoid(uf @ p["wi"] + p["bi"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * rgate  # [B,T,R]
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (igate * uf)

    if mode == "decode":
        h0 = cache["h"]  # [B, R]
        h = a[:, 0] * h0 + gated_in[:, 0]
        out = h[:, None]
        new_cache = {"h": h, "conv": conv_state.astype(jnp.float32)}
    else:
        h0 = None if cache is None else cache["h"]
        out = _lru_scan(a, gated_in, h0)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "h": out[:, -1],
                "conv": conv_state.astype(jnp.float32),
            }

    y = (out.astype(adt) * gate) @ p["wo"].astype(adt)
    return y, new_cache
