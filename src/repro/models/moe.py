"""Mixture-of-Experts layer: top-k routing with capacity-bucketed dispatch.

GShard-style *grouped* dispatch: each sequence (batch row) is a dispatch
group with its own per-expert capacity buckets, so all scatter/cumsum work
is local to a group and the whole layer shards cleanly -- groups follow the
batch (DP) sharding, the batched expert einsum shards over E (expert
parallelism) or over the ffn dim when E doesn't divide the model axis
(grok's 8 experts on a 16-wide axis).  Tokens overflowing an expert's
capacity are dropped (combine weight zero), the standard capacity-factor
trade-off.

This mirrors the paper's fused-minibatch insight (Sec. III-B): tokens
routed to one expert are *fused* into a single matmul so the expert weights
are fetched from HBM once per bucket -- the MoE analogue of reusing the
sparse matrix across slices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, maybe_constrain


def moe_init(key, cfg):
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1),
        "wg": dense_init(ks[2], (e, d, f), in_axis=1),
        "wo": dense_init(ks[3], (e, f, d), in_axis=1),
    }


def _dispatch_one_group(xf, top_e, top_p, e: int, cap: int):
    """One group's scatter: xf [T, D], top_e/top_p [T, k].

    Returns (buckets [E, cap, D], tok_idx [T*k], slot [T*k], keep [T*k]).
    """
    t, d = xf.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)  # [T*k] token-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)[
        jnp.arange(t * k), flat_e
    ]
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)  # overflow -> trash slot
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buckets = jnp.zeros((e, cap + 1, d), xf.dtype)
    buckets = buckets.at[flat_e, slot].set(xf[tok_idx], mode="drop")
    return buckets[:, :cap], tok_idx, slot, keep


def moe_apply(p, x, *, cfg):
    """x: [B, T, D] -> ([B, T, D], aux_loss scalar)."""
    b, t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = max(1, int(cfg.moe_capacity_factor * k * t / e))
    adt = x.dtype

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [B, T, E]
    top_p, top_e = jax.lax.top_k(probs, k)  # [B, T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    buckets, tok_idx, slot, keep = jax.vmap(
        lambda xf, te, tp: _dispatch_one_group(xf, te, tp, e, cap)
    )(x, top_e, top_p)  # buckets [B, E, cap, D]

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    if cfg.shard_hints:
        # Anchor dispatch to data parallelism (and experts to the model
        # axis when divisible): XLA's propagation otherwise replicated
        # the bucket gradient across DP and all-reduced 80 GiB/layer at
        # 512 chips (EXPERIMENTS.md §Perf iteration 4).
        espec = "model" if e % 16 == 0 else None
        buckets = maybe_constrain(
            buckets, (cfg.dp_axes, espec, None, None)
        )
    h = jnp.einsum("becd,edf->becf", buckets, p["wi"].astype(adt))
    g = jnp.einsum("becd,edf->becf", buckets, p["wg"].astype(adt))
    out = jnp.einsum("becf,efd->becd", act(g) * h, p["wo"].astype(adt))
    if cfg.shard_hints:
        espec = "model" if e % 16 == 0 else None
        out = maybe_constrain(out, (cfg.dp_axes, espec, None, None))

    def combine(out_b, flat_e, slot_b, keep_b, tok_b, w_b):
        out_ext = jnp.concatenate(
            [out_b, jnp.zeros((e, 1, d), out_b.dtype)], axis=1
        )
        gathered = out_ext[flat_e, jnp.where(keep_b, slot_b, cap)]
        w = (w_b * keep_b).astype(adt)
        return jnp.zeros((t, d), adt).at[tok_b].add(
            gathered * w[:, None]
        )

    y = jax.vmap(combine)(
        out,
        top_e.reshape(b, -1),
        slot,
        keep,
        tok_idx,
        top_p.reshape(b, -1),
    )

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e.
    frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    aux = e * jnp.sum(frac * probs.mean((0, 1)))
    return y, aux
