"""Neural architecture substrate: transformer, MoE, recurrent blocks, LM heads."""
