"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows arXiv:2405.04517.  Both use exponential gating with the
log-domain stabilizer state m_t so gates never overflow:

  mLSTM (per head, head dim = hd):
    C_t = f_t C_{t-1} + i_t v_t k_t^T     (matrix memory [hd, hd])
    n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)

  sLSTM (per channel, heads give block-diagonal recurrence):
    c_t = f_t c_{t-1} + i_t z_t ;  n_t = f_t n_{t-1} + i_t
    h_t = o_t * c_t / n_t

Sequence processing is a ``lax.scan`` over time (the chunkwise-parallel
form is a known optimization, recorded as future work in EXPERIMENTS.md
§Perf notes); decode is one step.  Block wrappers follow the paper:
mLSTM block = up-proj x2 (gate/value), causal conv on the value path,
q/k/v from it, cell, gated down-proj; sLSTM block = cell + gated FFN.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init
from .rglru import _conv1d


# --------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------- #


def mlstm_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    dn = cfg.mlstm_expansion * d  # inner width
    hd = dn // h
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, dn)),
        "w_gate": dense_init(ks[1], (d, dn)),
        "conv": dense_init(ks[2], (cfg.conv_width, dn)) * 0.1,
        "wq": dense_init(ks[3], (dn, dn)),
        "wk": dense_init(ks[4], (dn, dn)),
        "wv": dense_init(ks[5], (dn, dn)),
        "w_if": dense_init(ks[6], (dn, 2 * h)),  # input+forget gates/head
        "b_if": jnp.concatenate(
            [jnp.zeros((h,)), 3.0 + jnp.arange(h, dtype=jnp.float32)]
        ),
        "skip": jnp.ones((dn,), jnp.float32),
        "w_down": dense_init(ks[7], (dn, d)),
    }


def _mlstm_cell_step(state, qkvif, hd):
    """One time step.  state = (C [B,H,hd,hd], n [B,H,hd], m [B,H])."""
    c, n, m = state
    q, k, v, ig, fg = qkvif  # q/k/v [B,H,hd]; ig/fg [B,H] (pre-activation)
    log_f = -jax.nn.softplus(-fg)  # log sigmoid
    m_new = jnp.maximum(log_f + m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    n = f_p * n + i_p * k
    c = f_p[..., None] * c + i_p[..., None] * (
        v[..., :, None] * k[..., None, :]
    )
    qn = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0
    )[..., None]
    h = jnp.einsum("bhde,bhe->bhd", c, q) / qn
    return (c, n, m_new), h


def mlstm_apply(p, x, *, cfg, cache=None, mode="train"):
    """Returns (y, cache); cache = {C, n, m, conv}."""
    adt = x.dtype
    b, t, d = x.shape
    nh = cfg.n_heads
    dn = p["w_up"].shape[1]
    hd = dn // nh

    up = x @ p["w_up"].astype(adt)
    gate = x @ p["w_gate"].astype(adt)
    cv, conv_state = _conv1d(
        up, p["conv"], None if cache is None else cache["conv"]
    )
    cv = jax.nn.silu(cv)
    q = (cv @ p["wq"].astype(adt)).reshape(b, t, nh, hd)
    k = (cv @ p["wk"].astype(adt)).reshape(b, t, nh, hd) / math.sqrt(hd)
    v = (up @ p["wv"].astype(adt)).reshape(b, t, nh, hd)
    gif = cv.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    ig, fg = gif[..., :nh], gif[..., nh:]  # [B,T,H]

    if cache is None:
        c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh), jnp.float32)
    else:
        c0, n0, m0 = cache["C"], cache["n"], cache["m"]

    qf, kf, vf = (z.astype(jnp.float32) for z in (q, k, v))
    if mode == "decode":
        state, h = _mlstm_cell_step(
            (c0, n0, m0),
            (qf[:, 0], kf[:, 0], vf[:, 0], ig[:, 0], fg[:, 0]),
            hd,
        )
        h = h[:, None]
    else:
        def step(s, inp):
            return _mlstm_cell_step(s, inp, hd)
        state, h = jax.lax.scan(
            step,
            (c0, n0, m0),
            (
                qf.transpose(1, 0, 2, 3),
                kf.transpose(1, 0, 2, 3),
                vf.transpose(1, 0, 2, 3),
                ig.transpose(1, 0, 2),
                fg.transpose(1, 0, 2),
            ),
        )
        h = h.transpose(1, 0, 2, 3)  # [B,T,H,hd]

    h = h.reshape(b, -1, dn).astype(adt)
    h = h + p["skip"].astype(adt) * cv[:, : h.shape[1]]
    y = (h * jax.nn.silu(gate[:, : h.shape[1]])) @ p["w_down"].astype(adt)
    new_cache = None
    if mode in ("prefill", "decode"):
        cc, nn, mm = state
        new_cache = {
            "C": cc, "n": nn, "m": mm,
            "conv": conv_state.astype(jnp.float32),
        }
    return y, new_cache


# --------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------- #


def slstm_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 4)
    f = int(cfg.slstm_ff_factor * d)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d)),  # z, i, f, o pre-acts
        "r_in": dense_init(ks[1], (d, 4 * d)) * 0.5,  # recurrent (blockwise)
        "b_in": jnp.concatenate(
            [
                jnp.zeros((d,)), jnp.zeros((d,)),
                jnp.full((d,), 3.0), jnp.zeros((d,)),
            ]
        ),
        "ff_wi": dense_init(ks[2], (d, 2 * f)),
        "ff_wo": dense_init(ks[3], (f, d)),
    }


def _slstm_cell_step(state, inp, w_r, b):
    """state = (c, n, m, h_prev) each [B, D]; inp = x_t [B, D] pre-proj."""
    c, n, m, h_prev = state
    pre = inp + h_prev @ w_r + b  # [B, 4D]
    d = c.shape[-1]
    z = jnp.tanh(pre[:, :d])
    ig = pre[:, d : 2 * d]
    fg = pre[:, 2 * d : 3 * d]
    o = jax.nn.sigmoid(pre[:, 3 * d :])
    log_f = -jax.nn.softplus(-fg)
    m_new = jnp.maximum(log_f + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h), h


def slstm_apply(p, x, *, cfg, cache=None, mode="train"):
    """Returns (y, cache); cache = {c, n, m, h}."""
    adt = x.dtype
    b, t, d = x.shape
    pre = (x.astype(jnp.float32)) @ p["w_in"]  # [B,T,4D]
    if cache is None:
        state = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(4))
    else:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])

    if mode == "decode":
        state, h = _slstm_cell_step(state, pre[:, 0], p["r_in"], p["b_in"])
        h = h[:, None]
    else:
        def step(s, inp):
            return _slstm_cell_step(s, inp, p["r_in"], p["b_in"])
        state, h = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
        h = h.transpose(1, 0, 2)

    h = h.astype(adt)
    f2 = p["ff_wi"].shape[1] // 2
    ff = h @ p["ff_wi"].astype(adt)
    h = jax.nn.gelu(ff[..., :f2]) * ff[..., f2:]
    y = h @ p["ff_wo"].astype(adt)
    new_cache = None
    if mode in ("prefill", "decode"):
        c, n, m, hh = state
        new_cache = {"c": c, "n": n, "m": m, "h": hh}
    return y, new_cache
