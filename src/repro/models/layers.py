"""Core transformer layers: norms, position encodings, GQA attention, MLP.

Pure JAX (no flax); parameters are nested dicts of arrays; every block
exposes ``init(key, cfg) -> params`` and
``apply(params, x, *, cfg, pos, cache, mode) -> (y, cache)`` with
``mode in {"train", "prefill", "decode"}``.

Supports the variations required by the assigned architectures:
  * GQA with any kv-head count (incl. MQA kv=1 and MHA kv=H)
  * qk-norm (qwen3), qkv bias (qwen1.5/codeqwen)
  * sliding-window ("local") attention (recurrentgemma)
  * RoPE, M-RoPE (qwen2-vl section-wise), sinusoidal (musicgen), none
  * gated (SiLU/GeLU) and plain MLPs; RMSNorm and LayerNorm
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- #
# initialization helpers
# --------------------------------------------------------------------- #


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape) * std).astype(
        dtype
    )


def maybe_constrain(x, spec):
    """Best-effort ``with_sharding_constraint`` (no-op without a mesh).

    Used for the §Perf sharding hints: under the production mesh the
    constraint anchors XLA's propagation; in single-device tests or
    meshes lacking the named axes it silently does nothing.
    """
    try:
        from jax.sharding import PartitionSpec as _P

        return jax.lax.with_sharding_constraint(x, _P(*spec))
    except Exception:  # noqa: BLE001 -- no mesh context / missing axes
        return x


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #


def norm_init(d, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# position encodings
# --------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, *, sections=None, theta: float = 10000.0):
    """Rotary embedding; ``x``: [B, T, N, hd], positions: [B, T] (int).

    ``sections``: M-RoPE (qwen2-vl) -- tuple of per-section *pair* counts
    summing to hd//2; ``positions`` then has shape [n_sections, B, T]
    (temporal / height / width streams; the text stub feeds the same ids to
    all three, which is exactly M-RoPE's behaviour on text tokens).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    if sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,T,hd/2]
    else:
        assert sum(sections) == hd // 2, (sections, hd)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            f = freqs[start : start + sec]
            parts.append(
                positions[i][..., None].astype(jnp.float32) * f
            )
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # [B,T,hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    """Classic transformer sinusoidal table, evaluated at ``positions``."""
    half = d_model // 2
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- #
# attention (GQA / MQA / MHA, optional sliding window)
# --------------------------------------------------------------------- #


def attn_init(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def _attn_mask(q_pos, k_pos, window: int):
    """[.., Tq, Tk] boolean mask: causal, optionally banded."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def attn_apply(p, x, *, cfg, positions, cache=None, mode="train",
               window: int = 0):
    """Returns (y, new_cache).

    cache (prefill out / decode in-out):
      {"k": [B, C, KV, hd], "v": ..., "pos": scalar int32 next-write pos}
      For windowed attention C == window and writes wrap (rolling buffer).
    """
    b, t, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    adt = x.dtype

    q = x @ p["wq"].astype(adt)
    k = x @ p["wk"].astype(adt)
    v = x @ p["wv"].astype(adt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)
        k = k + p["bk"].astype(adt)
        v = v + p["bv"].astype(adt)
    q = q.reshape(b, t, h, hd)
    k = k.reshape(b, t, kv, hd)
    v = v.reshape(b, t, kv, hd)
    if cfg.qk_norm:
        q = norm_apply(p["q_norm"], q, cfg.norm_eps)
        k = norm_apply(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope == "rope":
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
    elif cfg.rope == "mrope":
        mpos = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_rope(q, mpos, sections=cfg.mrope_sections)
        k = apply_rope(k, mpos, sections=cfg.mrope_sections)

    scale = 1.0 / math.sqrt(hd)
    g = h // kv  # query groups per kv head

    if mode == "decode":
        # t == 1; read rolling/linear cache, write at pos.
        assert cache is not None
        c = cache["k"].shape[1]
        pos = cache["pos"]  # int32 scalar: current write position
        slot = pos % c if window > 0 else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype)[:, :1], (0, slot, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype)[:, :1], (0, slot, 0, 0)
        )
        if window > 0:
            base = pos - pos % c
            k_pos = jnp.arange(c) + base
            k_pos = jnp.where(k_pos > pos, k_pos - c, k_pos)  # unwrap ring
        else:
            k_pos = jnp.arange(c)
        valid = (k_pos <= pos) & (k_pos > pos - window if window > 0
                                  else k_pos >= 0)
        qh = q.reshape(b, 1, kv, g, hd)
        logits = jnp.einsum(
            "bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
            ck.astype(jnp.float32)
        ) * scale
        logits = jnp.where(valid[None, None, None, None, :], logits,
                           -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, cv.astype(jnp.float32))
        o = o.reshape(b, 1, h * hd).astype(adt)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    else:
        qh = q.reshape(b, t, kv, g, hd)
        if cfg.shard_hints and cfg.attn_q_shard:
            # kv-heads don't divide the model axis: shard the *query time*
            # dim instead and let scores/softmax/PV inherit it (context
            # parallelism).  Anchoring the input -- not the score tensor --
            # keeps XLA's propagation consistent through mask + softmax;
            # without this XLA partial-sums the [B,kv,g,T,T] fp32 scores
            # across model (56 GiB AR per layer at 32k prefill;
            # EXPERIMENTS.md §Perf iteration 3).
            qh = maybe_constrain(
                qh, (cfg.dp_axes, "model", None, None, None)
            )
        logits = jnp.einsum(
            "bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
            k.astype(jnp.float32)
        ) * scale
        if cfg.shard_hints and cfg.attn_heads_merge:
            # kv doesn't divide the model axis but kv*g does: anchor the
            # merged head dim so XLA factors the axis across (kv, g).
            lg2 = logits.reshape(b, kv * g, t, -1)
            lg2 = maybe_constrain(
                lg2, (cfg.dp_axes, "model", None, None)
            )
            logits = lg2.reshape(b, kv, g, t, -1)
        elif cfg.shard_hints and not cfg.attn_q_shard:
            logits = maybe_constrain(
                logits, (cfg.dp_axes, "model", None, None, None)
            )
        mask = _attn_mask(positions, positions, window)  # [B,T,T]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
        o = o.reshape(b, t, h * hd).astype(adt)
        new_cache = None
        if mode == "prefill":
            c = window if window > 0 else cfg.max_cache
            cdt = cfg.cache_dtype
            if window > 0 and t >= c:
                ck = k[:, t - c :].astype(cdt)
                cv = v[:, t - c :].astype(cdt)
                # ring layout: slot = pos % c; ensure slot of next token
                # (pos=t) lines up: roll so that index (t % c) is oldest.
                shift = t % c
                ck = jnp.roll(ck, shift, axis=1)
                cv = jnp.roll(cv, shift, axis=1)
            else:
                pad = c - t
                ck = jnp.pad(
                    k.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0))
                )
                cv = jnp.pad(
                    v.astype(cdt), ((0, 0), (0, pad), (0, 0), (0, 0))
                )
            new_cache = {"k": ck, "v": cv, "pos": jnp.int32(t)}
    y = o @ p["wo"].astype(adt)
    return y, new_cache


# --------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------- #


def mlp_init(key, cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, f)),
        "wo": dense_init(ks[1], (f, d)),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], (d, f))
    return p


def mlp_apply(p, x, *, cfg):
    adt = x.dtype
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = x @ p["wi"].astype(adt)
    if "wg" in p:
        h = act(x @ p["wg"].astype(adt)) * h
    else:
        h = act(h)
    return h @ p["wo"].astype(adt)
