"""LM task heads: loss, train_step, prefill, decode_step.

Two gradient-sync modes (the paper's Sec. III-C + III-D applied to data
parallelism):

  * ``spmd``  -- plain global-batch pjit; XLA inserts the DP all-reduce.
  * ``hier``  -- shard_map manual over the DP axes ("data" fast ICI, "pod"
    slow DCI), auto over "model" (TP stays XLA-managed).  Per-shard grads
    are cast to the comm dtype with *adaptive normalization* (power-of-two
    max-norm rescale) and reduced with the hierarchical ladder:
    reduce-scatter over "data", all-reduce over "pod" at 1/|data| volume,
    all-gather back -- only locally-reduced data crosses the slow links,
    exactly the paper's local-reduction trick.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..core.precision import qcast
from ..dist import Topology
from ..dist.collectives import hierarchical_psum
from .transformer import forward, init_cache  # noqa: F401

__all__ = [
    "loss_fn",
    "make_train_step",
    "make_hier_train_step",
    "prefill",
    "decode_step",
]


def loss_fn(params, cfg, batch):
    """Next-token cross entropy (+ MoE aux).  batch: tokens or embeds."""
    inputs = batch["inputs"]
    labels = batch["labels"]  # [B, T] int32
    b, t = labels.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    logits, _, aux = forward(
        params, cfg, inputs, positions=positions, mode="train"
    )
    # Predict token t+1 at position t.  Vocab-parallel-safe formulation:
    # ``lse - target_logit`` rather than materializing log_softmax over
    # the full vocabulary -- with the unembedding sharded on V, logsumexp
    # reduces the sharded axis locally (tiny [B,T] all-reduce) whereas the
    # naive form forced a full [B,T,V] fp32 replication (measured 32 GiB
    # all-reduce + 44 GB/dev temp at 512 chips; EXPERIMENTS.md §Perf it.1).
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = labels[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)  # [B, T-1]
    # one-hot contraction (not take_along_axis): fuses to a local reduce
    # over the sharded vocab dim, and avoids an XLA crash when gathered
    # under partial-manual shard_map (hier grad sync path).
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype)
    tgt_logit = jnp.einsum("btv,btv->bt", lg, onehot)
    nll = lse - tgt_logit
    loss = nll.mean()
    return loss + cfg.moe_aux_weight * aux, {"nll": loss, "aux": aux}


def make_train_step(cfg, optimizer):
    """Global-batch (pjit / spmd) train step."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def make_hier_train_step(
    cfg,
    optimizer,
    mesh,
    dp_axes=("data", "pod"),
    comm_dtype=jnp.bfloat16,
    adaptive: bool = True,
):
    """Paper-style hierarchical mixed-precision gradient sync.

    Returns a function with the same signature as ``make_train_step``'s,
    to be called under ``jax.jit``; the body is shard_map-manual over
    ``dp_axes`` and auto over everything else ("model").
    """
    from jax.sharding import PartitionSpec as P

    dp_axes = tuple(a for a in dp_axes if a in mesh.shape)
    # DP ladder: "data" is the fast (major-ICI) level, "pod" the slow
    # (DCI) one; TP stays on "model" outside the topology (XLA-managed).
    topo = Topology.from_mesh(mesh, data_axes=dp_axes, batch_axes=())
    ndp = topo.n_data

    def local_step(params, opt_state, batch):
        # Per-DP-shard mean loss; no DP reduction inserted by XLA here
        # (batch dims are shard-local under manual axes).
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)

        def sync(g):
            gc, inv = qcast(
                g, comm_dtype, adaptive=adaptive, axis_name=dp_axes
            )
            if jax.default_backend() != "tpu":
                # XLA CPU backend crashes on bf16 collectives under
                # partial-manual shard_map ("invalid binary opcode copy").
                # Quantization already happened in qcast; carry f32 on the
                # wire here, native narrow dtype on TPU.  Wire-byte
                # accounting uses the comm dtype analytically.
                gc = gc.astype(jnp.float32)
            summed = hierarchical_psum(gc, topo, mode="hier")
            return summed.astype(jnp.float32) * (inv / ndp)

        grads = jax.tree.map(sync, grads)
        loss = jax.lax.pmean(loss, dp_axes)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}

    batch_spec = {"inputs": P(dp_axes), "labels": P(dp_axes)}
    rep = jax.tree.map(lambda _: P(), {"d": 0})["d"]  # P() replicated

    def specs_like(tree):
        return jax.tree.map(lambda _: rep, tree)

    if jax.default_backend() == "tpu":
        manual_axes = set(dp_axes)  # TP stays XLA-managed (auto)
    else:
        # XLA:CPU's SPMD partitioner check-fails (IsManualSubgroup) on
        # partially-manual shard_map; go fully manual off-TPU.  The
        # "model" axis then carries replicated compute inside the step
        # -- identical values, no tensor parallelism on this backend.
        manual_axes = set(mesh.axis_names)

    def step(params, opt_state, batch):
        return jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                specs_like(params), specs_like(opt_state), batch_spec
            ),
            out_specs=(
                specs_like(params),
                specs_like(opt_state),
                jax.tree.map(lambda _: rep, {"loss": 0, "nll": 0,
                                             "aux": 0}),
            ),
            axis_names=manual_axes,
            check_vma=False,
        )(params, opt_state, batch)

    return step


def prefill(params, cfg, inputs):
    """Full-sequence prefill: returns (last-token logits, cache).

    Only the last position is unembedded (``last_token_only``): computing
    logits for all T positions costs ``T x`` the unembed matmul + its TP
    collective and is pure waste in serving (measured as the dominant
    collective in the 32k-prefill dry-runs; EXPERIMENTS.md §Perf it.2).
    """
    if cfg.embed_inputs:
        b, t = inputs.shape
    else:
        b, t = inputs.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    logits, cache, _ = forward(
        params, cfg, inputs, positions=positions, mode="prefill",
        last_token_only=True,
    )
    return logits[:, -1], cache


def decode_step(params, cfg, cache, token, pos):
    """One decode step.

    Args:
      token: [B, 1] int32 (or [B, 1, D] embeds for stub frontends).
      pos: scalar int32 position of this token.

    Returns (next_token [B, 1], new_cache, logits [B, V]).
    """
    b = token.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    logits, new_cache, _ = forward(
        params, cfg, token, positions=positions, cache=cache, mode="decode"
    )
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], new_cache, logits[:, -1]
