"""Unified decoder assembly: scan-over-layers, heterogeneous block patterns.

Layers are grouped into *periods* (one cycle of ``cfg.block_pattern``);
full periods are processed under ``jax.lax.scan`` with period-stacked
parameters (compact HLO -- essential for compiling 62-layer models for 512
devices), and a trailing partial period is unrolled.  KV caches / recurrent
states follow the same stacking.

Modes: "train" (full-seq causal, no cache), "prefill" (full-seq, emits
cache), "decode" (one token, consumes+emits cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers as L
from .moe import moe_apply, moe_init
from .rglru import rglru_apply, rglru_init
from .xlstm import mlstm_apply, mlstm_init, slstm_apply, slstm_init

__all__ = ["init_params", "forward", "init_cache"]


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #


def _layer_init(key, kind: str, cfg):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.norm_init(cfg.d_model, cfg.norm)}
    if kind in ("attn", "local"):
        p["attn"] = L.attn_init(ks[0], cfg)
        p["ln2"] = L.norm_init(cfg.d_model, cfg.norm)
        p["mix"] = (
            moe_init(ks[1], cfg) if cfg.moe_experts else L.mlp_init(ks[1], cfg)
        )
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks[0], cfg)
        p["ln2"] = L.norm_init(cfg.d_model, cfg.norm)
        p["mix"] = L.mlp_init(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg, key):
    pattern = tuple(cfg.block_pattern)
    period = len(pattern)
    n_per, rem = divmod(cfg.n_layers, period)
    keys = jax.random.split(key, 4)
    params = {}
    if cfg.embed_inputs:
        params["embed"] = L.dense_init(
            keys[0], (cfg.vocab_size, cfg.d_model), in_axis=1
        )
    if not (cfg.tie_embeddings and cfg.embed_inputs):
        params["unembed"] = L.dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size)
        )
    params["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)

    if n_per:
        pkeys = jax.random.split(keys[2], n_per)

        def one_period(k):
            sub = jax.random.split(k, period)
            return {
                f"l{j}": _layer_init(sub[j], pattern[j], cfg)
                for j in range(period)
            }

        params["scan"] = jax.vmap(one_period)(pkeys)  # leaves [n_per, ...]
    if rem:
        rkeys = jax.random.split(keys[3], rem)
        params["rem"] = {
            f"l{j}": _layer_init(rkeys[j], pattern[j], cfg)
            for j in range(rem)
        }
    return params


# --------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------- #


def _layer_cache(kind: str, cfg, batch: int):
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    if kind in ("attn", "local"):
        c = cfg.window if kind == "local" else cfg.max_cache
        return {
            "k": jnp.zeros((batch, c, kv, hd), cfg.cache_dtype),
            "v": jnp.zeros((batch, c, kv, hd), cfg.cache_dtype),
            "pos": jnp.int32(0),
        }
    r = cfg.rnn_width or cfg.d_model
    if kind == "rglru":
        return {
            "h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, r), jnp.float32),
        }
    if kind == "mlstm":
        dn = cfg.mlstm_expansion * cfg.d_model
        nh = cfg.n_heads
        return {
            "C": jnp.zeros((batch, nh, dn // nh, dn // nh), jnp.float32),
            "n": jnp.zeros((batch, nh, dn // nh), jnp.float32),
            "m": jnp.zeros((batch, nh), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, dn), jnp.float32),
        }
    if kind == "slstm":
        d = cfg.d_model
        z = lambda: jnp.zeros((batch, d), jnp.float32)  # noqa: E731
        return {"c": z(), "n": z(), "m": z(), "h": z()}
    raise ValueError(kind)


def init_cache(cfg, batch: int):
    pattern = tuple(cfg.block_pattern)
    period = len(pattern)
    n_per, rem = divmod(cfg.n_layers, period)
    cache = {}
    if n_per:
        cache["scan"] = {
            f"l{j}": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_per,) + x.shape).copy(),
                _layer_cache(pattern[j], cfg, batch),
            )
            for j in range(period)
        }
    if rem:
        cache["rem"] = {
            f"l{j}": _layer_cache(pattern[j], cfg, batch)
            for j in range(rem)
        }
    return cache


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #


def _layer_apply(kind, p, x, *, cfg, positions, cache, mode):
    aux = jnp.float32(0.0)
    h = L.norm_apply(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        a, c = L.attn_apply(
            p["attn"], h, cfg=cfg, positions=positions, cache=cache,
            mode=mode, window=cfg.window if kind == "local" else 0,
        )
        x = x + a
        h2 = L.norm_apply(p["ln2"], x, cfg.norm_eps)
        if cfg.moe_experts:
            m, aux = moe_apply(p["mix"], h2, cfg=cfg)
        else:
            m = L.mlp_apply(p["mix"], h2, cfg=cfg)
        x = x + m
    elif kind == "rglru":
        a, c = rglru_apply(p["rglru"], h, cfg=cfg, cache=cache, mode=mode)
        x = x + a
        h2 = L.norm_apply(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mix"], h2, cfg=cfg)
    elif kind == "mlstm":
        a, c = mlstm_apply(p["mlstm"], h, cfg=cfg, cache=cache, mode=mode)
        x = x + a
    elif kind == "slstm":
        a, c = slstm_apply(p["slstm"], h, cfg=cfg, cache=cache, mode=mode)
        x = x + a
    else:
        raise ValueError(kind)
    return x, c, aux


def _period_apply(pattern, p, x, *, cfg, positions, cache, mode):
    new_cache = {}
    aux = jnp.float32(0.0)
    for j, kind in enumerate(pattern):
        c_in = None if cache is None else cache[f"l{j}"]
        x, c_out, a = _layer_apply(
            kind, p[f"l{j}"], x, cfg=cfg, positions=positions,
            cache=c_in, mode=mode,
        )
        aux = aux + a
        if c_out is not None:
            new_cache[f"l{j}"] = c_out
    return x, (new_cache or None), aux


def forward(params, cfg, inputs, *, positions, cache=None, mode="train",
            last_token_only: bool = False):
    """Run the decoder.

    Args:
      inputs: int tokens [B, T] (``cfg.embed_inputs``) or precomputed
        embeddings [B, T, D] (vlm/audio frontend stubs).
      positions: [B, T] int32 global positions.
      cache: pytree from ``init_cache`` ("decode"), or None.
      mode: train | prefill | decode.
      last_token_only: unembed only the final position (serving prefill).

    Returns:
      (logits [B, T, V] float32, new_cache or None, aux_loss scalar)
    """
    adt = cfg.activation_dtype
    pattern = tuple(cfg.block_pattern)
    period = len(pattern)
    n_per, rem = divmod(cfg.n_layers, period)

    if cfg.embed_inputs:
        x = jnp.take(params["embed"], inputs, axis=0).astype(adt)
    else:
        x = inputs.astype(adt)
    if cfg.rope == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(adt)

    aux_total = jnp.float32(0.0)
    new_cache = {"scan": None, "rem": None}

    if n_per:
        def body(carry, xs):
            xx, aux = carry
            p, c = xs
            xx, c_new, a = _period_apply(
                pattern, p, xx, cfg=cfg, positions=positions, cache=c,
                mode=mode,
            )
            return (xx, aux + a), c_new

        if mode == "train" and cfg.remat != "none":
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat == "dots"
                else None
            )
            body = jax.checkpoint(body, policy=policy)

        cache_scan = None if cache is None else cache["scan"]
        if cfg.scan_layers:
            if cache_scan is None:
                # scan requires matching pytree: use params only
                (x, aux_total), caches = jax.lax.scan(
                    lambda c, p: body(c, (p, None)),
                    (x, aux_total),
                    params["scan"],
                )
            else:
                (x, aux_total), caches = jax.lax.scan(
                    body, (x, aux_total), (params["scan"], cache_scan)
                )
            if mode in ("prefill", "decode") and caches is not None:
                new_cache["scan"] = caches
        else:
            # Unrolled layer stack (dry-run cost fidelity).
            caches_list = []
            for i in range(n_per):
                p_i = jax.tree.map(lambda l: l[i], params["scan"])
                c_i = (
                    None
                    if cache_scan is None
                    else jax.tree.map(lambda l: l[i], cache_scan)
                )
                (x, aux_total), c_new = body((x, aux_total), (p_i, c_i))
                caches_list.append(c_new)
            if mode in ("prefill", "decode") and caches_list[0] is not None:
                new_cache["scan"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *caches_list
                )

    if rem:
        rem_pattern = pattern[:rem]
        cache_rem = None if cache is None else cache["rem"]
        x, c_new, a = _period_apply(
            rem_pattern, params["rem"], x, cfg=cfg, positions=positions,
            cache=cache_rem, mode=mode,
        )
        aux_total = aux_total + a
        new_cache["rem"] = c_new

    if last_token_only:
        x = x[:, -1:]
    x = L.norm_apply(params["final_norm"], x, cfg.norm_eps)
    if "unembed" in params:
        w = params["unembed"]
    else:
        w = params["embed"].T
    logits = (x @ w.astype(adt)).astype(jnp.float32)
    out_cache = None
    if mode in ("prefill", "decode"):
        out_cache = {k: v for k, v in new_cache.items() if v is not None}
    return logits, out_cache, aux_total
