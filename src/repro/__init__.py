"""Petascale XCT reproduction: distributed 3D image reconstruction in JAX.

Subpackages:
  core     -- geometry, partitioning, precision, solver, reconstruction
  dist     -- topology-aware hierarchical communication (Topology/CommPlan)
  kernels  -- Pallas blocked-ELL SpMM + pure-jnp oracles
  models   -- LM substrate exercising the same communication machinery
  launch   -- drivers: recon, train, serve, dry-run lowering, perf sweeps
"""
from . import _compat

_compat.install()

__version__ = "0.1.0"
