"""Petascale XCT reproduction: distributed 3D image reconstruction in JAX.

Subpackages:
  core     -- geometry, partitioning, precision, solver, reconstruction
  dist     -- topology-aware hierarchical communication (Topology/CommPlan)
  kernels  -- Pallas blocked-ELL SpMM + pure-jnp oracles
  stream   -- out-of-core slab streaming (volumes larger than memory)
  serve    -- multi-tenant reconstruction-as-a-service (plan cache,
              admission control, batching, progressive previews)
  models   -- LM substrate exercising the same communication machinery
  launch   -- drivers: recon, train, lm_serve, dry-run lowering, sweeps
"""
from . import _compat

_compat.install()

__version__ = "0.1.0"
