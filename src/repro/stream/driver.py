"""Out-of-core streaming reconstruction: drain slabs through the solver.

``reconstruct_streaming`` turns a :class:`~repro.stream.store.SlabStore`
sinogram into a volume store without ever holding more than one slab (two
with prefetch) in host memory:

  1. size the slab from the byte budget (``scheduler.suggest_slab``) or
     take an explicit ``y_slab``;
  2. restore the resume manifest (``ckpt.checkpoint``) and skip slabs
     already recorded done -- slices are independent least-squares
     problems sharing ``A`` (parallel-beam, paper Sec. II-B), so a
     restart that re-solves only the remaining slabs converges to the
     identical volume;
  3. for each pending slab: prefetch slab ``i+1`` from disk -- and, by
     default, stage it host -> device (``Reconstructor.stage_sino``) --
     while slab ``i`` solves (``scheduler.Prefetcher``, the Fig. 8
     overlap lifted up the memory hierarchy: the jit argument transfer
     of the next slab hides under the current solve), run the in-memory
     ``Reconstructor.reconstruct`` on the staged slab, write the
     reconstructed slab to the volume store (atomic shard publish);
     per-slab wall time is split into load / upload / solve so the
     ``BENCH_stream`` artifacts show what each rung of the pipeline
     actually hides;
  4. checkpoint the manifest every ``k`` slabs, ``k`` from the measured
     slab/write times via the Young/Daly optimum
     (``dist.fault.suggest_checkpoint_period``) unless pinned by
     ``checkpoint_every``.

Because the per-slice math in ``Reconstructor.reconstruct`` never couples
slices (CG scalars, normalization, and the solve itself are all
column-wise), the streamed volume equals the one-shot in-memory volume
slice for slice, for *any* slab size -- pinned by
``tests/test_stream.py``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings

import numpy as np

from ..ckpt import checkpoint as ckpt
from ..core.recon import StagedSlab
from ..dist.fault import suggest_checkpoint_period
from ..obs import metrics as obs_metrics
from ..obs.trace import span
from .scheduler import Prefetcher, suggest_slab
from .store import SlabStore

UPLOAD_MODES = ("overlap", "sync")

__all__ = ["StreamResult", "reconstruct_streaming"]


@dataclasses.dataclass
class StreamResult:
    """What one (possibly resumed, possibly interrupted) drain did.

    Timing fields use the repo-wide ``*_s`` convention (seconds,
    float); the old ``*_seconds`` names remain as deprecated aliases
    for one release.  Every value is a span duration from
    :mod:`repro.obs.trace` -- with tracing enabled the exported
    ``stream/*`` spans and these fields are the same numbers.
    """

    volume: SlabStore  # the output store (complete iff slabs all done)
    resnorms: np.ndarray  # [iters, Y] per-slice residuals (0 = unsolved)
    y_slab: int
    solved: list  # slab starts solved by THIS call
    skipped: list  # slab starts skipped via the resume manifest
    slab_s: list  # critical-path wall seconds per solved slab
    # the per-slab pipeline split (parallel lists to ``solved``):
    load_s: list = dataclasses.field(default_factory=list)
    upload_s: list = dataclasses.field(default_factory=list)
    solve_s: list = dataclasses.field(default_factory=list)
    upload_overlapped: bool = False  # uploads ran off the critical path

    @property
    def complete(self) -> bool:
        return self.volume.complete()


def _alias(cls, old: str, new: str):
    """Deprecated ``*_seconds`` read alias for a renamed ``*_s`` field."""
    def get(self):
        warnings.warn(
            f"{cls.__name__}.{old} is deprecated; use .{new}",
            DeprecationWarning, stacklevel=2,
        )
        return getattr(self, new)

    get.__name__ = old
    get.__doc__ = f"Deprecated alias for :attr:`{new}`."
    setattr(cls, old, property(get))


for _old, _new in (
    ("slab_seconds", "slab_s"),
    ("load_seconds", "load_s"),
    ("upload_seconds", "upload_s"),
    ("solve_seconds", "solve_s"),
):
    _alias(StreamResult, _old, _new)


def _manifest_like(n_slabs: int, iters: int, n_slices: int) -> dict:
    return {
        "done": np.zeros(n_slabs, np.uint8),
        "res": np.zeros((iters, n_slices), np.float32),
        "y_slab": np.zeros((), np.int64),
    }


def reconstruct_streaming(
    rec,
    sino_store: SlabStore,
    out_dir: str,
    *,
    iters: int = 30,
    mem_budget: int | None = None,
    y_slab: int | None = None,
    ckpt_dir: str | None = None,
    overlap: bool = True,
    device_upload: str = "overlap",
    checkpoint_every: int | None = None,
    max_slabs: int | None = None,
) -> StreamResult:
    """Reconstruct a stored sinogram slab-by-slab into a volume store.

    Args:
      rec: a ``core.recon.Reconstructor`` (its plan's geometry must match
        the store's row count).
      sino_store: measurements, ``[n_rays, Y]`` in natural order.
      out_dir: directory for the output volume store (``[n_vox, Y]``).
      iters: CG iterations per slab (the paper's 30).
      mem_budget: total bytes for operator + in-flight slabs; sizes the
        slab via ``scheduler.suggest_slab``.  Exactly one of
        ``mem_budget`` / ``y_slab`` must be given.
      y_slab: explicit slab size (multiple of ``n_batch * fuse``).
      ckpt_dir: resume-manifest directory; restart skips slabs recorded
        done there.  ``None`` disables checkpointing.
      overlap: prefetch the next slab while the current one solves.
      device_upload: "overlap" (default) runs the host->device staging
        (``rec.stage_sino``: pack + normalize + jit-arg upload) in the
        prefetch thread too, double-buffering the device transfer the
        ROADMAP flagged as riding synchronously inside ``reconstruct``;
        "sync" keeps the upload on the critical path (A/B baseline --
        ``bench_stream`` sweeps both).  Results are bit-identical.
      checkpoint_every: manifest cadence in slabs; ``None`` derives it
        from measured slab/write costs (Young/Daly).
      max_slabs: stop after solving this many slabs (simulated
        preemption for tests/examples); the manifest is saved first.
    """
    if (mem_budget is None) == (y_slab is None):
        raise ValueError("pass exactly one of mem_budget= / y_slab=")
    if device_upload not in UPLOAD_MODES:
        raise ValueError(
            f"unknown device_upload {device_upload!r}; "
            f"one of {UPLOAD_MODES}"
        )
    geo = rec.plan.geo
    if sino_store.rows != geo.n_rays:
        raise ValueError(
            f"store has {sino_store.rows} rows, plan expects "
            f"{geo.n_rays} rays"
        )
    n_slices = sino_store.n_slices
    granule = rec.n_batch * rec.cfg.fuse
    if n_slices % granule:
        raise ValueError(
            f"slice count {n_slices} must be a multiple of "
            f"batch x fuse = {granule}"
        )
    if y_slab is None:
        y_slab = suggest_slab(
            rec.plan, rec.cfg, rec.topology, mem_budget,
            n_slices=n_slices, overlap=overlap,
        ).y_slab
    if y_slab % granule:
        raise ValueError(f"y_slab {y_slab} not a multiple of {granule}")
    volume = SlabStore.create(
        out_dir, geo.n_vox, n_slices, y_slab, np.float32
    )
    slabs = volume.slabs()

    # ---- resume manifest -------------------------------------------- #
    done = np.zeros(len(slabs), np.uint8)
    res = np.zeros((iters, n_slices), np.float32)
    if ckpt_dir is not None:
        step = ckpt.latest_step(ckpt_dir)
        if step is not None:
            try:
                state = ckpt.restore(
                    ckpt_dir, step,
                    _manifest_like(len(slabs), iters, n_slices),
                )
            except (ValueError, AssertionError) as e:
                # shape drift inside restore means the run parameters
                # changed; surface the actual knobs, not leaf shapes
                raise ValueError(
                    f"resume manifest in {ckpt_dir} does not match this "
                    f"run (y_slab={y_slab}, iters={iters}, "
                    f"Y={n_slices}); restart with the original settings "
                    f"or clear the manifest [{e}]"
                ) from e
            if int(state["y_slab"]) != y_slab:
                raise ValueError(
                    f"resume manifest was written with y_slab="
                    f"{int(state['y_slab'])}, this run uses {y_slab}"
                )
            done, res = state["done"], state["res"]

    def save_manifest():
        if ckpt_dir is None:
            return 0.0
        with span("stream/ckpt", step=int(done.sum())) as sp:
            ckpt.save(
                ckpt_dir, int(done.sum()),
                {"done": done, "res": res,
                 "y_slab": np.asarray(y_slab, np.int64)},
            )
        return sp.duration_s

    pending = [i for i in range(len(slabs)) if not done[i]]
    if max_slabs is not None:
        pending = pending[:max_slabs]
    skipped = [slabs[i][0] for i in range(len(slabs)) if done[i]]
    solved: list = []
    slab_s: list = []
    load_s: list = []
    upload_s: list = []
    solve_s: list = []
    n_nodes = max(1, rec.mesh.size)
    every = checkpoint_every
    since_save = 0

    up_overlap = device_upload == "overlap"
    fetch = lambda i: sino_store.read(*slabs[i])  # noqa: E731
    pre = Prefetcher(
        fetch, pending, depth=1, enabled=overlap,
        # host->device staging in the worker thread: slab i+1's upload
        # runs while slab i solves (ROADMAP: double-buffer the device
        # upload too)
        stage=rec.stage_sino if up_overlap else None,
    )
    for pos, (i, slab_in) in enumerate(pre):
        j0, j1 = slabs[i]
        # spans both time the pipeline rungs (their duration_s IS what
        # lands in StreamResult) and, when tracing is on, record the
        # Perfetto lanes the CI obs-smoke asserts on
        with span("stream/slab", slab=i, j0=j0) as sp_slab:
            if up_overlap:
                staged = slab_in  # StagedSlab, upload already done
                t_up = pre.times[pos]["stage"]
            else:
                with span("stream/upload", slab=i) as sp_up:
                    staged = rec.stage_sino(slab_in)
                t_up = sp_up.duration_s
            assert isinstance(staged, StagedSlab)
            with span("stream/solve", slab=i, iters=iters) as sp_solve:
                x, r = rec.reconstruct(staged, iters=iters)
            with span("stream/write", slab=i):
                volume.write(j0, x)
        dt = sp_slab.duration_s
        res[:, j0:j1] = r
        done[i] = 1
        solved.append(j0)
        slab_s.append(dt)
        load_s.append(pre.times[pos]["load"])
        upload_s.append(t_up)
        solve_s.append(sp_solve.duration_s)
        obs_metrics.inc("stream_slabs_total")
        since_save += 1
        if every is None and ckpt_dir is not None:
            # first slab: measure one save, then derive the Young/Daly
            # cadence from the measured write cost and slab time
            write_cost = save_manifest()
            since_save = 0
            period = suggest_checkpoint_period(
                max(write_cost, 1e-6), n_nodes
            )
            every = max(1, int(period / max(dt, 1e-9)))
        elif every is not None and since_save >= every:
            save_manifest()
            since_save = 0
    if since_save and ckpt_dir is not None:
        save_manifest()
    return StreamResult(
        volume=volume,
        resnorms=res,
        y_slab=int(y_slab),
        solved=solved,
        skipped=skipped,
        slab_s=slab_s,
        load_s=load_s,
        upload_s=upload_s,
        solve_s=solve_s,
        # with disk prefetch on, loads of slab i+1 hide under slab i's
        # solve; with device_upload="overlap" the upload does too
        upload_overlapped=bool(overlap and up_overlap),
    )
