"""Out-of-core streaming reconstruction: drain slabs through the solver.

``reconstruct_streaming`` turns a :class:`~repro.stream.store.SlabStore`
sinogram into a volume store without ever holding more than one slab (two
with prefetch) in host memory:

  1. size the slab from the byte budget (``scheduler.suggest_slab``) or
     take an explicit ``y_slab``;
  2. restore the resume manifest (``ckpt.checkpoint``) and skip slabs
     already recorded done -- slices are independent least-squares
     problems sharing ``A`` (parallel-beam, paper Sec. II-B), so a
     restart that re-solves only the remaining slabs converges to the
     identical volume;
  3. for each pending slab: prefetch slab ``i+1`` from disk -- and, by
     default, stage it host -> device (``Reconstructor.stage_sino``) --
     while slab ``i`` solves (``scheduler.Prefetcher``, the Fig. 8
     overlap lifted up the memory hierarchy), run the in-memory
     ``Reconstructor.reconstruct`` on the staged slab, write the
     reconstructed slab to the volume store (atomic shard publish);
     per-slab wall time is split into load / upload / solve so the
     ``BENCH_stream`` artifacts show what each rung of the pipeline
     actually hides;
  4. checkpoint the manifest every ``k`` slabs, ``k`` from the measured
     slab/write times via the Young/Daly optimum
     (``dist.fault.suggest_checkpoint_period``) unless pinned by
     ``checkpoint_every``.

The drain **self-heals** (see ``docs/fault_tolerance.md``):

* transient load/stage failures retry inside the prefetch worker under
  ``retry=`` (:class:`~repro.resil.RetryPolicy`, deterministic
  backoff); a worker that dies anyway gets one synchronous re-try at
  the driver before the slab is *quarantined* -- recorded in the resume
  manifest's ``failed`` array and ``StreamResult.failed_slabs``, the
  drain continues with the rest, and a later resume re-attempts it;
* a :class:`~repro.resil.NonFiniteSolveError` retries at the native
  precision (a transient blow-up heals bit-exactly), then re-solves
  **one precision rung up** (q8/fp8/half -> f32) before quarantining;
* per-slab load times feed a :class:`~repro.dist.fault.StragglerMonitor`;
  a flagged straggler shrinks the prefetch lookahead to zero (stop
  racing a struggling disk), emits a ``stream_prefetch_lookahead``
  gauge + ``stream/straggler`` trace instant, and the drain carries on
  synchronously.

Because the per-slice math in ``Reconstructor.reconstruct`` never couples
slices (CG scalars, normalization, and the solve itself are all
column-wise), the streamed volume equals the one-shot in-memory volume
slice for slice, for *any* slab size -- pinned by
``tests/test_stream.py``; the chaos scenarios above are pinned bit-exact
by ``tests/test_resil.py`` and the CI ``chaos-smoke`` gate.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..ckpt import checkpoint as ckpt
from ..core.recon import Reconstructor, StagedSlab
from ..dist.fault import StragglerMonitor, suggest_checkpoint_period
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..obs.trace import span
from ..resil import inject
from ..resil.errors import NonFiniteSolveError
from ..resil.retry import RetryPolicy
from .scheduler import Prefetcher, PrefetchError, suggest_slab
from .store import SlabStore

UPLOAD_MODES = ("overlap", "sync")

# graceful degradation: precision rung to re-solve at after a
# non-finite result exhausts same-rung retries (f32/f64 have nowhere
# safer to go -> straight to quarantine)
ESCALATION = {
    "q8": "single",
    "fp8": "single",
    "int8": "single",
    "half": "single",
    "f16": "single",
    "bf16": "single",
    "mixed": "single",
    "mixed_bf16": "single",
}

__all__ = ["StreamResult", "reconstruct_streaming", "ESCALATION"]


@dataclasses.dataclass
class StreamResult:
    """What one (possibly resumed, possibly interrupted) drain did.

    Timing fields use the repo-wide ``*_s`` convention (seconds,
    float).  Every value is a span duration from
    :mod:`repro.obs.trace` -- with tracing enabled the exported
    ``stream/*`` spans and these fields are the same numbers.
    """

    volume: SlabStore  # the output store (complete iff slabs all done)
    resnorms: np.ndarray  # [iters, Y] per-slice residuals (0 = unsolved)
    y_slab: int
    solved: list  # slab starts solved by THIS call
    skipped: list  # slab starts skipped via the resume manifest
    slab_s: list  # critical-path wall seconds per solved slab
    # the per-slab pipeline split (parallel lists to ``solved``):
    load_s: list = dataclasses.field(default_factory=list)
    upload_s: list = dataclasses.field(default_factory=list)
    solve_s: list = dataclasses.field(default_factory=list)
    upload_overlapped: bool = False  # uploads ran off the critical path
    # resilience outcome of this call:
    failed_slabs: list = dataclasses.field(default_factory=list)
    retries: int = 0  # load/stage/solve retries this call
    escalated: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    @property
    def complete(self) -> bool:
        return self.volume.complete()


def _manifest_like(n_slabs: int, iters: int, n_slices: int) -> dict:
    return {
        "done": np.zeros(n_slabs, np.uint8),
        "failed": np.zeros(n_slabs, np.uint8),
        "res": np.zeros((iters, n_slices), np.float32),
        "y_slab": np.zeros((), np.int64),
    }


def reconstruct_streaming(
    rec,
    sino_store: SlabStore,
    out_dir: str,
    *,
    iters: int = 30,
    mem_budget: int | None = None,
    y_slab: int | None = None,
    ckpt_dir: str | None = None,
    overlap: bool = True,
    device_upload: str = "overlap",
    checkpoint_every: int | None = None,
    max_slabs: int | None = None,
    retry: RetryPolicy | None = None,
    fail_fast: bool = False,
    straggler_k_mad: float = 4.0,
) -> StreamResult:
    """Reconstruct a stored sinogram slab-by-slab into a volume store.

    Args:
      rec: a ``core.recon.Reconstructor`` (its plan's geometry must match
        the store's row count).
      sino_store: measurements, ``[n_rays, Y]`` in natural order.
      out_dir: directory for the output volume store (``[n_vox, Y]``).
      iters: CG iterations per slab (the paper's 30).
      mem_budget: total bytes for operator + in-flight slabs; sizes the
        slab via ``scheduler.suggest_slab``.  Exactly one of
        ``mem_budget`` / ``y_slab`` must be given.
      y_slab: explicit slab size (multiple of ``n_batch * fuse``).
      ckpt_dir: resume-manifest directory; restart skips slabs recorded
        done there (quarantined slabs stay pending, so a resume
        re-attempts them).  ``None`` disables checkpointing.
      overlap: prefetch the next slab while the current one solves.
      device_upload: "overlap" (default) runs the host->device staging
        (``rec.stage_sino``) in the prefetch thread too; "sync" keeps
        the upload on the critical path (A/B baseline).  Results are
        bit-identical.
      checkpoint_every: manifest cadence in slabs; ``None`` derives it
        from measured slab/write costs (Young/Daly).
      max_slabs: stop after solving this many slabs (simulated
        preemption for tests/examples); the manifest is saved first.
      retry: :class:`~repro.resil.RetryPolicy` for transient
        load/stage/solve failures (``None`` -> the default policy:
        3 attempts, 50 ms base backoff).
      fail_fast: disable retry/quarantine -- the first failure
        propagates (debugging; the CLI's ``--fail-fast``).
      straggler_k_mad: robust z-score threshold for the per-slab load
        straggler monitor.

    A drain with quarantined slabs returns normally: the poison slabs
    are listed in ``StreamResult.failed_slabs`` (and counted by the
    ``slabs_quarantined_total`` metric), the rest of the volume is on
    disk, and ``result.complete`` is ``False`` -- the exit-code
    contract (``launch.recon`` exits 3 on a partial drain) lives at the
    CLI.
    """
    if (mem_budget is None) == (y_slab is None):
        raise ValueError("pass exactly one of mem_budget= / y_slab=")
    if device_upload not in UPLOAD_MODES:
        raise ValueError(
            f"unknown device_upload {device_upload!r}; "
            f"one of {UPLOAD_MODES}"
        )
    geo = rec.plan.geo
    if sino_store.rows != geo.n_rays:
        raise ValueError(
            f"store has {sino_store.rows} rows, plan expects "
            f"{geo.n_rays} rays"
        )
    n_slices = sino_store.n_slices
    granule = rec.n_batch * rec.cfg.fuse
    if n_slices % granule:
        raise ValueError(
            f"slice count {n_slices} must be a multiple of "
            f"batch x fuse = {granule}"
        )
    if y_slab is None:
        y_slab = suggest_slab(
            rec.plan, rec.cfg, rec.topology, mem_budget,
            n_slices=n_slices, overlap=overlap,
        ).y_slab
    if y_slab % granule:
        raise ValueError(f"y_slab {y_slab} not a multiple of {granule}")
    policy = retry if retry is not None else RetryPolicy()
    volume = SlabStore.create(
        out_dir, geo.n_vox, n_slices, y_slab, np.float32
    )
    slabs = volume.slabs()

    # ---- resume manifest -------------------------------------------- #
    done = np.zeros(len(slabs), np.uint8)
    failed = np.zeros(len(slabs), np.uint8)
    res = np.zeros((iters, n_slices), np.float32)
    if ckpt_dir is not None:
        step = ckpt.latest_step(ckpt_dir)
        if step is not None:
            try:
                state = ckpt.restore(
                    ckpt_dir, step,
                    _manifest_like(len(slabs), iters, n_slices),
                )
            except (ValueError, AssertionError) as e:
                # shape drift inside restore means the run parameters
                # changed; surface the actual knobs, not leaf shapes
                raise ValueError(
                    f"resume manifest in {ckpt_dir} does not match this "
                    f"run (y_slab={y_slab}, iters={iters}, "
                    f"Y={n_slices}); restart with the original settings "
                    f"or clear the manifest [{e}]"
                ) from e
            if int(state["y_slab"]) != y_slab:
                raise ValueError(
                    f"resume manifest was written with y_slab="
                    f"{int(state['y_slab'])}, this run uses {y_slab}"
                )
            done, failed, res = (
                state["done"], state["failed"], state["res"]
            )

    def save_manifest():
        if ckpt_dir is None:
            return 0.0
        with span("stream/ckpt", step=int(done.sum())) as sp:
            ckpt.save(
                ckpt_dir, int(done.sum()),
                {"done": done, "failed": failed, "res": res,
                 "y_slab": np.asarray(y_slab, np.int64)},
            )
        return sp.duration_s

    pending = [i for i in range(len(slabs)) if not done[i]]
    if max_slabs is not None:
        pending = pending[:max_slabs]
    skipped = [slabs[i][0] for i in range(len(slabs)) if done[i]]
    solved: list = []
    slab_s: list = []
    load_s: list = []
    upload_s: list = []
    solve_s: list = []
    failed_slabs: list = []
    escalated: list = []
    stragglers: list = []
    n_retries = 0
    n_nodes = max(1, rec.mesh.size)
    every = checkpoint_every
    since_save = 0
    monitor = StragglerMonitor(k_mad=straggler_k_mad, window=1)

    up_overlap = device_upload == "overlap"
    lookahead = 1 if overlap else 0
    fetch = lambda i: sino_store.read(*slabs[i])  # noqa: E731
    # host->device staging in the worker thread: slab i+1's upload runs
    # while slab i solves
    stage_fn = rec.stage_sino if up_overlap else None

    esc_cache: dict = {}

    def escalated_rec():
        """Lazily build the one-rung-up solver (shares the plan; only
        the precision policy -- and hence the operator packing --
        differs)."""
        if "rec" not in esc_cache:
            target = ESCALATION.get(rec.cfg.precision)
            esc_cache["rec"] = None if target is None else Reconstructor(
                rec.plan,
                cfg=dataclasses.replace(rec.cfg, precision=target),
                topology=rec.topology,
            )
        return esc_cache["rec"]

    def solve_slab(i, staged):
        """Solve with heal: same-rung retries, then one rung up.

        Raises ``NonFiniteSolveError`` when every rung blew up -- the
        caller quarantines.
        """
        nonlocal n_retries
        attempt = 0
        solver = rec
        while True:
            try:
                with span(
                    "stream/solve", slab=i, iters=iters, retry=attempt,
                    precision=solver.cfg.precision,
                ) as sp:
                    with inject.scope(i):
                        x, r = solver.reconstruct(staged, iters=iters)
                if solver is not rec:
                    escalated.append(slabs[i][0])
                    obs_metrics.inc("stream_escalations_total")
                return x, r, sp.duration_s
            except NonFiniteSolveError:
                if fail_fast:
                    raise
                attempt += 1
                if attempt < policy.max_attempts:
                    n_retries += 1
                    obs_metrics.inc("retries_total", site="stream/solve")
                    obs_trace.instant(
                        "resil/retry", site="stream/solve", key=str(i),
                        attempt=attempt, error="NonFiniteSolveError",
                    )
                    d = policy.delay_s("stream/solve", i, attempt)
                    if d > 0.0:
                        time.sleep(d)
                    continue
                nxt = escalated_rec() if solver is rec else None
                if nxt is None:
                    raise  # both rungs poisoned -> quarantine
                solver = nxt  # one try at the escalated rung

    def quarantine(i, exc):
        j0 = slabs[i][0]
        failed[i] = 1
        failed_slabs.append(j0)
        obs_metrics.inc("slabs_quarantined_total")
        obs_trace.instant(
            "stream/quarantine", slab=i, j0=j0,
            error=type(exc).__name__,
        )
        save_manifest()  # record the quarantine durably, off-cadence

    def process(i, slab_in, t_load, t_stage):
        """Upload + solve + write + bookkeeping for one fetched slab."""
        nonlocal every, since_save
        j0, j1 = slabs[i]
        with span("stream/slab", slab=i, j0=j0) as sp_slab:
            if isinstance(slab_in, StagedSlab):
                staged, t_up = slab_in, t_stage
            else:
                with span("stream/upload", slab=i) as sp_up:
                    staged = rec.stage_sino(slab_in)
                t_up = sp_up.duration_s
            assert isinstance(staged, StagedSlab)
            try:
                x, r, t_solve = solve_slab(i, staged)
            except NonFiniteSolveError as e:
                if fail_fast:
                    raise
                quarantine(i, e)
                return
            with span("stream/write", slab=i):
                volume.write(j0, x)
        dt = sp_slab.duration_s
        res[:, j0:j1] = r
        done[i] = 1
        failed[i] = 0  # a resumed quarantined slab that now solved
        solved.append(j0)
        slab_s.append(dt)
        load_s.append(t_load)
        upload_s.append(t_up)
        solve_s.append(t_solve)
        obs_metrics.inc("stream_slabs_total")
        since_save += 1
        if every is None and ckpt_dir is not None:
            # first slab: measure one save, then derive the Young/Daly
            # cadence from the measured write cost and slab time
            write_cost = save_manifest()
            since_save = 0
            period = suggest_checkpoint_period(
                max(write_cost, 1e-6), n_nodes
            )
            every = max(1, int(period / max(dt, 1e-9)))
        elif every is not None and since_save >= every:
            save_manifest()
            since_save = 0
        # the crash-resume property test's preemption point: fires
        # AFTER this slab's work (and its cadenced manifest save)
        inject.fire("stream/after_slab", key=i)

    # ---- drain ------------------------------------------------------ #
    # Outer loop restarts the prefetch pipeline after any structural
    # event (quarantine, worker death, straggler-driven lookahead
    # shrink); each segment drains `remaining` until one occurs.
    remaining = list(pending)
    while remaining:
        pre = Prefetcher(
            fetch, remaining, depth=lookahead, enabled=lookahead > 0,
            stage=stage_fn, retry=None if fail_fast else policy,
        )
        gen = iter(pre)
        pos = -1
        try:
            while True:
                pos += 1
                try:
                    i, slab_in = next(gen)
                except StopIteration:
                    remaining = []
                    break
                except PrefetchError as e:
                    if fail_fast:
                        raise
                    # worker-level retries are already exhausted (or the
                    # failure was non-retryable, e.g. the worker thread
                    # died): one synchronous driver-level re-try, then
                    # quarantine
                    i = e.item
                    n_retries += 1
                    obs_metrics.inc("retries_total", site="stream/slab")
                    try:
                        raw = fetch(i)
                        slab_in = stage_fn(raw) if stage_fn else raw
                    except Exception as e2:  # noqa: BLE001
                        quarantine(i, e2)
                    else:
                        process(i, slab_in, 0.0, 0.0)
                    remaining = remaining[e.index + 1:]
                    break
                tm = pre.times.get(pos, {})
                process(
                    i, slab_in, tm.get("load", 0.0), tm.get("stage", 0.0)
                )
                monitor.record(i, tm.get("load", 0.0))
                if lookahead > 0:
                    bad = monitor.stragglers()
                    if bad:
                        # a struggling disk: stop racing ahead of it
                        stragglers.extend(
                            b for b in bad if b not in stragglers
                        )
                        lookahead = 0
                        obs_metrics.set_gauge(
                            "stream_prefetch_lookahead", 0.0
                        )
                        obs_metrics.inc("stream_stragglers_total")
                        obs_trace.instant(
                            "stream/straggler", slabs=str(bad)
                        )
                        remaining = remaining[pos + 1:]
                        break
        finally:
            gen.close()  # drop the lookahead worker before rebuilding
        n_retries += pre.retries
    if since_save and ckpt_dir is not None:
        save_manifest()
    return StreamResult(
        volume=volume,
        resnorms=res,
        y_slab=int(y_slab),
        solved=solved,
        skipped=skipped,
        slab_s=slab_s,
        load_s=load_s,
        upload_s=upload_s,
        solve_s=solve_s,
        # with disk prefetch on, loads of slab i+1 hide under slab i's
        # solve; with device_upload="overlap" the upload does too
        upload_overlapped=bool(overlap and up_overlap),
        failed_slabs=failed_slabs,
        retries=n_retries,
        escalated=escalated,
        stragglers=stragglers,
    )
