"""Slab scheduling: size I/O batches from a memory budget, prefetch ahead.

The paper's Sec. III-E structures one reconstruction as ``Y`` slices
drained in I/O batches, each solved as ``Y_slab / F`` fused minibatches.
This module picks ``Y_slab`` from a *byte budget* instead of assuming the
whole volume fits:

  budget >= fixed + Y_slab * per_slice

``fixed`` is the resident operator footprint (both blocked-ELL shards,
``OperatorShards.hbm_bytes`` at the precision policy's storage width --
parallel-beam geometry shares one ``A`` across every slab, so streaming
re-pays this never).  ``per_slice`` is the per-slice working set:

  * CGNR state on device, summed over the data-parallel shards: three
    tomogram-space vectors (``x``, ``p``, ``s``) and three sinogram-space
    vectors (``y``, ``r``, ``q``) per slice, kept f32 (4 B) -- see
    ``core.solver.cgnr``;
  * host staging of the sinogram slab in and the volume slab out
    (``4 * (rows_pad + cols_pad)``), doubled when the prefetcher
    double-buffers (slab ``i+1`` loads while slab ``i`` solves), plus
    the next slab's device-staged sinogram (``4 * rows_pad``) under the
    driver's default device-upload overlap.

``Y_slab`` is rounded down to the solve granule ``n_batch * fuse``
(``Reconstructor`` requires it) and capped at ``Y``.  The plan also
carries the modeled HBM traffic of one slab solve
(``kernels.traffic.spmm_traffic``, the same model the roofline sweeps
use) and the kernel's VMEM double-buffer footprint
(``kernels.xct_spmm.vmem_bytes``) so callers can report modeled
arithmetic intensity per slab without re-deriving byte counts.

:class:`Prefetcher` is the host half of the Fig. 8 overlap, one level up
the hierarchy: a single background thread fetches slab ``i+1`` from the
store while the solver owns slab ``i`` -- same pipeline shape as the
in-solve minibatch overlap (``core.pipeline``), applied to disk -> host
instead of compute -> wire.  With a ``stage=`` callable it also covers
the *next* rung: the thread runs host -> device staging (e.g.
``Reconstructor.stage_sino``) right after the disk read, so slab
``i+1``'s upload hides under slab ``i``'s solve too.  Fetch/stage wall
times are recorded per item (``Prefetcher.times``) and thread failures
surface at the consuming ``next()`` as :class:`PrefetchError` naming
the failing item -- a dead prefetch thread can no longer hang the
drain loop silently.
"""
from __future__ import annotations

import dataclasses
import math
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Sequence

from ..obs.trace import span
from ..resil import inject
from ..resil.retry import RetryPolicy, call_with_retry

__all__ = ["SlabPlan", "suggest_slab", "Prefetcher", "PrefetchError"]


class PrefetchError(RuntimeError):
    """A background fetch/stage failed.

    Raised by :class:`Prefetcher` at the consuming ``next()`` -- never
    swallowed in the worker thread -- with the failing item and its
    position attached so a driver can checkpoint/skip deterministically.
    """

    def __init__(self, item, index: int, cause: BaseException):
        self.item = item
        self.index = index
        self.cause = cause
        super().__init__(
            f"prefetch of item {item!r} (index {index}) failed: "
            f"{type(cause).__name__}: {cause}"
        )


@dataclasses.dataclass(frozen=True)
class SlabPlan:
    """A sized streaming schedule (see :func:`suggest_slab`)."""

    y_slab: int  # slices per I/O batch (multiple of granule)
    granule: int  # n_batch * fuse, the solve quantum
    fixed_bytes: int  # resident operator footprint
    per_slice_bytes: int  # working set per slice (device + host staging)
    slab_hbm_bytes: float  # modeled kernel HBM traffic per slab per iter
    slab_flops: float  # modeled kernel FLOPs per slab per iter
    vmem_bytes: int  # kernel double-buffer footprint per device

    @property
    def slab_bytes(self) -> int:
        """Peak bytes while one slab is in flight."""
        return self.fixed_bytes + self.y_slab * self.per_slice_bytes

    def n_slabs(self, n_slices: int) -> int:
        return int(math.ceil(n_slices / self.y_slab))


def _op_traffic(op, fuse: int, storage_bytes: int,
                vals_bytes: int | None = None) -> tuple[float, float]:
    from ..kernels.traffic import op_segments_per_stage, spmm_traffic

    _, b, s, r, k = op.inds.shape
    t = spmm_traffic(
        b, s, r, k, op.winmap.shape[-1], fuse,
        storage_bytes=storage_bytes, vals_bytes=vals_bytes,
        staging="fused",
        # measured winsegs tables for real plans, est capacity for
        # abstract ones -- same dispatch as xct_perf/dryrun, so the
        # BENCH_stream 'ai' the CI gate pins is the measured model
        segments_per_stage=op_segments_per_stage(op),
    )
    return t["hbm_bytes"], t["flops"]


def suggest_slab(
    plan,
    cfg,
    topology,
    mem_budget: int,
    *,
    n_slices: int | None = None,
    overlap: bool = True,
    passport=None,
) -> SlabPlan:
    """Pick the largest budget-fitting ``Y_slab`` for a partition plan.

    Args:
      plan: ``core.partition.Plan`` (real or ``estimate_plan`` abstract --
        only static shapes are consulted, so budget planning at xct-brain
        scale allocates nothing).
      cfg: ``core.recon.ReconConfig`` (fuse + precision drive the model).
      topology: ``dist.Topology``; its batch size sets the solve granule.
      mem_budget: total bytes available for operator + in-flight slabs.
      n_slices: optional total Y; caps the slab at the whole volume.
      overlap: double-buffered host staging (2x the slab staging bytes).
      passport: optional ``repro.tune.TuningPassport``; its tuned
        ``y_slab`` knob *caps* the granted slab (never raises it past
        what the budget allows -- the budget stays the authority,
        the passport only stops over-allocation the tuner found
        unprofitable).

    Raises ``ValueError`` when even one granule of slices overflows the
    budget (the operator alone may already be too large).
    """
    from ..core.precision import get_policy
    from ..kernels.xct_spmm import vmem_bytes

    pol = get_policy(cfg.precision)
    sb = pol.storage_bytes
    vb = pol.vals_bytes  # operator value width (1 for q8/fp8 tiers)
    proj, back = plan.proj, plan.back
    fixed = proj.hbm_bytes(value_bytes=vb) + back.hbm_bytes(value_bytes=vb)
    rows_pad, cols_pad = proj.n_rows_pad, proj.n_cols_pad
    # 3 tomo-space + 3 sino-space f32 CG vectors, + (1 or 2 with the
    # prefetch double buffer) host staging copies of slab-in + slab-out,
    # + with overlap the next slab's device-staged sinogram
    # (StagedSlab.y: the driver's default device_upload="overlap" keeps
    # slab i+1 resident on device while slab i solves)
    staging_copies = 2 if overlap else 1
    per_slice = 4 * (3 + staging_copies) * (rows_pad + cols_pad)
    if overlap:
        per_slice += 4 * rows_pad
    granule = max(1, topology.n_batch) * cfg.fuse
    avail = mem_budget - fixed
    y_slab = (avail // per_slice // granule) * granule
    if y_slab < granule:
        need = fixed + granule * per_slice
        raise ValueError(
            f"mem_budget={mem_budget} cannot hold one solve granule of "
            f"{granule} slices (needs >= {need} bytes: {fixed} operator "
            f"+ {granule}x{per_slice} working set)"
        )
    if n_slices is not None:
        y_slab = min(y_slab, (n_slices // granule) * granule or granule)
    if passport is not None:
        cap = passport.knobs.get("y_slab")
        if cap:
            y_slab = min(y_slab, max(granule, cap // granule * granule))
    hbm = flops = 0.0
    vmem = 0
    minis = y_slab // granule  # fused minibatches per batch member
    for op in (proj, back):
        h, f = _op_traffic(op, cfg.fuse, sb, vb)
        hbm += h * minis
        flops += f * minis
        _, _, s, r, k = op.inds.shape
        vmem = max(
            vmem,
            vmem_bytes(r, k, op.winmap.shape[-1], cfg.fuse, vb,
                       win_bytes=sb),
        )
    return SlabPlan(
        y_slab=int(y_slab),
        granule=int(granule),
        fixed_bytes=int(fixed),
        per_slice_bytes=int(per_slice),
        slab_hbm_bytes=hbm,
        slab_flops=flops,
        vmem_bytes=int(vmem),
    )


class Prefetcher:
    """Iterate ``(item, stage(fetch(item)))`` with background lookahead.

    One worker thread keeps ``depth`` fetches in flight ahead of the
    consumer: while the solver owns slab ``i``, slab ``i+1`` streams
    disk -> host (``fetch``) and, when ``stage=`` is given, host ->
    device (e.g. ``Reconstructor.stage_sino``) -- the whole staging
    ladder off the critical path.  ``depth=0`` (or ``enabled=False``)
    degrades to a plain synchronous loop -- the A/B knob
    ``bench_stream`` sweeps; ``stage`` still applies (inline) so
    results never depend on the schedule.

    Per-item wall times land in ``self.times[position] = {"load": s,
    "stage": s}`` (keyed by the item's position in ``items`` -- items
    themselves may be unhashable or duplicated) as each item is
    produced.  A failure in the worker thread re-raises at the
    consuming ``next()`` as :class:`PrefetchError` carrying the failing
    item and position.

    With ``retry=RetryPolicy(...)`` transient fetch/stage failures
    (``resil.RETRYABLE_IO``: disk errors, corrupt shards, timeouts)
    retry *in the worker* with deterministic backoff before anything
    surfaces -- a recovered hiccup costs one backoff, not a drain-loop
    round trip.  ``self.retries`` counts them; only exhausted (or
    non-retryable, e.g. a dying worker thread) failures become
    :class:`PrefetchError`.
    """

    def __init__(
        self,
        fetch: Callable,
        items: Sequence | Iterable,
        *,
        depth: int = 1,
        enabled: bool = True,
        stage: Callable | None = None,
        retry: RetryPolicy | None = None,
    ):
        self._fetch = fetch
        self._stage = stage
        self._items = list(items)
        self._depth = depth if enabled else 0
        self._retry = retry
        self.times: dict = {}
        self.retries = 0

    def __len__(self) -> int:
        return len(self._items)

    def _note_retry(self):
        self.retries += 1

    def _produce(self, pos, item):
        # spans always measure (their durations feed self.times and,
        # through the driver, StreamResult); with tracing on they land
        # on the worker thread's own Perfetto lane.  Retried attempts
        # carry retry=<n> so obs.drift excludes them from the model
        # join; the last (successful) attempt's time is what lands in
        # self.times.
        key = item if isinstance(item, int) else pos

        def load(attempt):
            with span("stream/load", pos=pos, retry=attempt) as sp:
                inject.fire("stream/load", key=key)
                out = self._fetch(item)
            self.times[pos] = {"load": sp.duration_s, "stage": 0.0}
            return out

        if self._retry is None:
            out = load(0)
        else:
            out = call_with_retry(
                load, policy=self._retry, site="stream/load", key=key,
                on_retry=self._note_retry,
            )
        if self._stage is not None:
            def stage_one(attempt):
                with span("stream/stage", pos=pos, retry=attempt) as sp:
                    inject.fire("stream/stage", key=key)
                    staged = self._stage(out)
                self.times[pos]["stage"] = sp.duration_s
                return staged

            if self._retry is None:
                out = stage_one(0)
            else:
                out = call_with_retry(
                    stage_one, policy=self._retry, site="stream/stage",
                    key=key, on_retry=self._note_retry,
                )
        return out

    def __iter__(self):
        if self._depth <= 0:
            for i, it in enumerate(self._items):
                try:
                    out = self._produce(i, it)
                except Exception as e:  # noqa: BLE001
                    raise PrefetchError(it, i, e) from e
                yield it, out
            return
        with ThreadPoolExecutor(max_workers=1) as pool:
            pending = []
            idx = 0
            # lookahead is exactly `depth`: pending futures + the one
            # yielded slab bound resident slabs at depth+1, matching the
            # staging copies suggest_slab budgets for
            while idx < len(self._items) and len(pending) < self._depth:
                pending.append(
                    (idx, self._items[idx],
                     pool.submit(self._produce, idx, self._items[idx]))
                )
                idx += 1
            while pending:
                i, item, fut = pending.pop(0)
                try:
                    out = fut.result()
                except Exception as e:  # noqa: BLE001
                    # surface the *failing slab* at the consumer instead
                    # of leaving the drain loop to starve on a dead
                    # worker.  Pool teardown waits for the already-
                    # submitted lookahead fetch to finish (running
                    # futures cannot be cancelled), so the error lands
                    # after at most one extra slab's worth of I/O.
                    raise PrefetchError(item, i, e) from e
                if idx < len(self._items):
                    pending.append(
                        (idx, self._items[idx],
                         pool.submit(self._produce, idx,
                                     self._items[idx]))
                    )
                    idx += 1
                yield item, out
