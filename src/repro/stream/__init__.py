"""Out-of-core slab streaming: reconstruct volumes that don't fit in RAM.

``store``     -- chunked, manifest-backed on-disk sinogram/volume stores
                 (slab-aligned shards, atomic tmp+rename publishes);
``scheduler`` -- budget -> slab sizing (``suggest_slab``) and the
                 double-buffered host prefetcher;
``driver``    -- ``reconstruct_streaming``: drain slabs through the
                 in-memory ``Reconstructor`` with a ``ckpt``-backed
                 resume manifest.

See docs/architecture.md ("Out-of-core streaming") for the slab-size
formula and the overlap schedule.
"""
from .driver import StreamResult, reconstruct_streaming
from .scheduler import PrefetchError, Prefetcher, SlabPlan, suggest_slab
from .store import SlabStore, simulate_to_store

__all__ = [
    "SlabStore",
    "simulate_to_store",
    "SlabPlan",
    "suggest_slab",
    "Prefetcher",
    "PrefetchError",
    "StreamResult",
    "reconstruct_streaming",
]
