"""Chunked, manifest-backed on-disk stores for sinograms and volumes.

The paper's datasets (9K x 11K x 11K mouse brain) are terabytes; neither
the sinogram ``[n_rays, Y]`` nor the volume ``[n_vox, Y]`` fits in host
RAM.  A :class:`SlabStore` keeps such a 2D array on disk as *slab-aligned
shards* along the slice axis (the paper's natural streaming unit,
Sec. III-E: slices are independent least-squares problems sharing ``A``):

  <dir>/manifest.json          rows, n_slices, slab, dtype  (written once)
  <dir>/slab_000000_000016.npy  slices [0, 16)
  <dir>/slab_000016_000032.npy  slices [16, 32)
  ...

Writes are slab-granular, *atomic* and *durable* (tmp + fsync +
``os.replace`` + directory fsync, the same publish discipline as
``ckpt.checkpoint``): a crash mid-write never leaves a torn shard and a
crash right after the rename cannot publish one either -- the data hits
the platter before the name does.  Each write also records the shard's
crc32 in the manifest (under ``"checksums"``, keyed ``"<j0>_<j1>"``);
``read`` verifies a shard the first time it touches it and raises a
typed :class:`~repro.resil.errors.CorruptShardError` on mismatch, which
the retry layer treats as retryable-once-then-quarantine.  Verification
is cached per ``(path, mtime)`` so steady-state reads stay memmap-fast;
the cache is bypassed while a fault plan is active (injected corruption
must never be masked by it).  Reads are range-granular -- ``read(j0,
j1)`` assembles any slice range from the covering shards via memmap, so
a scheduler is free to drain the store in slabs larger than the
writer's (e.g. the simulator writes fine-grained slabs, the solver
reads budget-sized ones).

``simulate_to_store`` is the streaming test-fixture writer: it generates
phantom slices and forward-projects them slab-by-slab
(``data.phantom.phantom_slices(start=, stop=)`` +
``simulate_measurements(first_slice=)``), so building a ``Y``-slice
sinogram never materializes more than one slab of ``[n_rays, slab]`` on
the host -- and the result is bit-identical to the one-shot simulation
for any slab size.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
import zlib

import numpy as np

from ..resil import inject
from ..resil.errors import CorruptShardError

__all__ = ["SlabStore", "simulate_to_store"]

_SHARD_RE = re.compile(r"^slab_(\d{6})_(\d{6})\.npy$")

# the manifest's identity keys; create() re-open compares only these
# (the "checksums" map grows with every write)
_STATIC_KEYS = ("rows", "n_slices", "slab", "dtype")


def _crc(arr) -> int:
    """crc32 of an array's raw bytes (the integrity unit is the shard's
    array data, not the .npy file, so header changes never alarm)."""
    a = np.ascontiguousarray(arr)
    return zlib.crc32(a.view(np.uint8).reshape(-1)) & 0xFFFFFFFF


def _fsync_dir(directory: str) -> None:
    """Durably record a rename in its directory (best-effort on
    platforms that cannot fsync a directory fd)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - platform-dependent
        pass
    finally:
        os.close(fd)


def _write_json(path: str, obj: dict) -> None:
    """Durable atomic JSON publish (fsync + replace + dir fsync)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


class SlabStore:
    """A ``[rows, n_slices]`` array stored as slab shards along axis 1."""

    def __init__(self, directory: str, manifest: dict):
        self.directory = directory
        self.rows = int(manifest["rows"])
        self.n_slices = int(manifest["n_slices"])
        self.slab = int(manifest["slab"])
        self.dtype = np.dtype(manifest["dtype"])
        self._checksums = dict(manifest.get("checksums", {}))
        self._verified: dict = {}  # shard path -> mtime at verification
        self._lock = threading.Lock()  # manifest read-modify-write

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    @classmethod
    def create(
        cls,
        directory: str,
        rows: int,
        n_slices: int,
        slab: int,
        dtype=np.float32,
    ) -> "SlabStore":
        """Create (or re-open, if the manifest matches) a store."""
        if slab <= 0 or n_slices <= 0 or rows <= 0:
            raise ValueError((rows, n_slices, slab))
        manifest = {
            "rows": int(rows),
            "n_slices": int(n_slices),
            "slab": int(slab),
            "dtype": np.dtype(dtype).name,
        }
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, "manifest.json")
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
            if {k: existing.get(k) for k in _STATIC_KEYS} != manifest:
                raise ValueError(
                    f"store at {directory} already exists with a "
                    f"different manifest: {existing} vs {manifest}"
                )
            # keep the recorded checksums when re-opening (resume path)
            manifest = existing
        else:
            _write_json(path, manifest)
        return cls(directory, manifest)

    @classmethod
    def open(cls, directory: str) -> "SlabStore":
        with open(os.path.join(directory, "manifest.json")) as f:
            return cls(directory, json.load(f))

    # ------------------------------------------------------------------ #
    # slab geometry
    # ------------------------------------------------------------------ #
    def slabs(self) -> list[tuple[int, int]]:
        """All ``(j0, j1)`` write-granularity slab ranges, in order."""
        return [
            (j0, min(j0 + self.slab, self.n_slices))
            for j0 in range(0, self.n_slices, self.slab)
        ]

    def _shard_path(self, j0: int, j1: int) -> str:
        return os.path.join(
            self.directory, f"slab_{j0:06d}_{j1:06d}.npy"
        )

    def written_slabs(self) -> list[tuple[int, int]]:
        """Slab ranges whose shards exist on disk (completion record)."""
        out = []
        for name in os.listdir(self.directory):
            m = _SHARD_RE.match(name)
            if m:
                out.append((int(m.group(1)), int(m.group(2))))
        return sorted(out)

    def complete(self) -> bool:
        return self.written_slabs() == self.slabs()

    # ------------------------------------------------------------------ #
    # I/O
    # ------------------------------------------------------------------ #
    def write(self, j0: int, arr) -> str:
        """Durably + atomically write the slab starting at slice ``j0``.

        ``arr`` must be exactly one write-granularity slab (``[rows,
        j1 - j0]`` with ``j0`` slab-aligned); re-writing a slab replaces
        it atomically.  The shard's crc32 lands in the manifest *before*
        the rename publishes the shard, and both the shard bytes and the
        rename are fsynced -- a crash at any point leaves either the old
        state or the new shard with a matching recorded checksum, never
        a torn shard the resume manifest believes is done.
        """
        arr = np.asarray(arr)
        if j0 % self.slab or not 0 <= j0 < self.n_slices:
            raise ValueError(
                f"slab start {j0} not aligned to slab={self.slab}"
            )
        j1 = min(j0 + self.slab, self.n_slices)
        if arr.shape != (self.rows, j1 - j0):
            raise ValueError(
                f"slab [{j0},{j1}) wants shape {(self.rows, j1 - j0)}, "
                f"got {arr.shape}"
            )
        stored = arr.astype(self.dtype, copy=False)
        final = self._shard_path(j0, j1)
        fd, tmp = tempfile.mkstemp(
            dir=self.directory, suffix=".npy.tmp"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                np.save(f, stored)
                f.flush()
                os.fsync(f.fileno())
            self._record_checksum(j0, j1, _crc(stored))
            os.replace(tmp, final)  # atomic publish
            _fsync_dir(self.directory)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._verified[final] = os.path.getmtime(final)
        return final

    def _record_checksum(self, j0: int, j1: int, crc: int) -> None:
        path = os.path.join(self.directory, "manifest.json")
        with self._lock:
            with open(path) as f:
                manifest = json.load(f)
            manifest.setdefault("checksums", {})[f"{j0}_{j1}"] = int(crc)
            manifest["checksum_algo"] = "crc32"
            _write_json(path, manifest)
            self._checksums[f"{j0}_{j1}"] = int(crc)

    def _load_shard(self, s0: int, s1: int, path: str) -> np.ndarray:
        """One shard, integrity-checked on first touch.

        Verification reads the shard once and caches ``(path, mtime)``;
        later reads memmap straight through.  While a fault plan is
        active the cache is bypassed and the ``store/read`` injection
        site is consulted (key = shard start slice), so injected
        io_error / corrupt / slow faults land here -- exactly where the
        real ones would.
        """
        recorded = self._checksums.get(f"{s0}_{s1}")
        injecting = inject.active()
        mtime = os.path.getmtime(path)
        if not injecting and (
            recorded is None or self._verified.get(path) == mtime
        ):
            # legacy shard (no recorded crc) or already verified
            return np.load(path, mmap_mode="r")
        shard = np.load(path, mmap_mode="r")
        if injecting:
            shard = inject.mutate("store/read", np.asarray(shard), key=s0)
        if recorded is not None:
            got = _crc(shard)
            if got != recorded:
                raise CorruptShardError(
                    f"shard [{s0},{s1}) of {self.directory} is corrupt: "
                    f"crc {got:#010x} != recorded {recorded:#010x}"
                )
            if not injecting:
                self._verified[path] = mtime
        return shard

    def read(self, j0: int, j1: int) -> np.ndarray:
        """Assemble slices ``[j0, j1)`` from the covering shards.

        Raises :class:`~repro.resil.errors.CorruptShardError` when a
        shard's bytes do not match its recorded crc (see
        :meth:`_load_shard`).
        """
        if not 0 <= j0 < j1 <= self.n_slices:
            raise ValueError((j0, j1, self.n_slices))
        out = np.empty((self.rows, j1 - j0), self.dtype)
        j = j0
        while j < j1:
            s0 = (j // self.slab) * self.slab
            s1 = min(s0 + self.slab, self.n_slices)
            path = self._shard_path(s0, s1)
            if not os.path.exists(path):
                raise FileNotFoundError(
                    f"slab [{s0},{s1}) of {self.directory} not written"
                )
            shard = self._load_shard(s0, s1, path)
            hi = min(j1, s1)
            out[:, j - j0 : hi - j0] = shard[:, j - s0 : hi - s0]
            j = hi
        return out

    # ------------------------------------------------------------------ #
    # convenience (tests / small arrays)
    # ------------------------------------------------------------------ #
    @classmethod
    def from_array(
        cls, directory: str, arr, slab: int
    ) -> "SlabStore":
        arr = np.asarray(arr)
        store = cls.create(
            directory, arr.shape[0], arr.shape[1], slab, arr.dtype
        )
        for j0, j1 in store.slabs():
            store.write(j0, arr[:, j0:j1])
        return store

    def to_array(self) -> np.ndarray:
        return self.read(0, self.n_slices)


def simulate_to_store(
    a_csr,
    n: int,
    store: SlabStore,
    *,
    noise: float = 0.0,
    seed: int = 0,
) -> SlabStore:
    """Fill ``store`` with simulated measurements, slab by slab.

    Each slab generates its phantom slices and forward-projects them
    independently (chunk-invariant: ``phantom_slices`` slab ranges and
    ``simulate_measurements`` per-slice noise streams depend only on the
    global slice index), so the host working set is one slab, never the
    full ``[n_rays, Y]``.
    """
    from ..data.phantom import phantom_slices, simulate_measurements

    for j0, j1 in store.slabs():
        x = phantom_slices(
            n, store.n_slices, seed=seed, start=j0, stop=j1
        )
        y = simulate_measurements(
            a_csr, x, noise=noise, seed=seed, first_slice=j0
        )
        store.write(j0, y)
    return store
