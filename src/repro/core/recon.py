"""End-to-end distributed XCT reconstruction (the paper's system, in JAX).

``Reconstructor`` binds a partition plan to a TPU mesh and exposes
``project`` / ``backproject`` / ``reconstruct``.  The whole CG solve runs
inside one ``shard_map``: per-device blocked-ELL SpMM (Pallas kernel) ->
mixed-precision cast with adaptive normalization -> partial-data reduction
(direct / reduce-scatter / hierarchical / sparse footprint exchange /
hierarchical-sparse socket-deduplicated exchange) ->
CGNR update, with slice-minibatches software-pipelined so reductions overlap
the next minibatch's kernel (paper Fig. 8).

Mesh-axis roles follow the paper's optimal partitioning strategy
(Sec. III-A3): in-slice data parallelism (which communicates) lives on the
*fast* axes; batch parallelism over slices (which doesn't) on the slow ones.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist import Topology
from ..dist.collectives import sparse_exchange
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..obs.trace import span as obs_span
from ..resil import inject
from ..resil.errors import NonFiniteSolveError
from ..kernels.ops import (
    apply_operator,
    sort_segments_by_class,
    winmap_segments,
)
from .hilbert import hilbert_argsort  # noqa: F401  (re-export convenience)
from .partition import (
    Plan,
    build_hier_sparse_exchange,
    build_sparse_exchange,
    estimate_hier_sparse,
)
from .pipeline import pipelined_apply
from .precision import (
    adaptive_scale_cols,
    get_policy,
    qcast,
    quantize_block_vals,
)
from .solver import cgnr

__all__ = ["ReconConfig", "Reconstructor", "StagedSlab"]


@dataclasses.dataclass(frozen=True)
class StagedSlab:
    """A sinogram slab already packed, normalized and on device.

    Produced by :meth:`Reconstructor.stage_sino`; pass it to
    :meth:`Reconstructor.reconstruct` in place of the natural-order
    numpy slab to skip the host->device staging inside the solve.  The
    streaming driver stages slab ``i+1`` from its prefetch thread while
    slab ``i`` solves (the Fig. 8 overlap applied to the jit argument
    transfer) -- results are bit-identical either way because the same
    pack/scale/transfer runs, just earlier.
    """

    y: object  # [sino_pad, Y] f32 device array, pre-scaled
    scale: np.ndarray  # [Y] power-of-two per-slice normalization
    n_slices: int


@dataclasses.dataclass(frozen=True)
class ReconConfig:
    precision: str = "mixed"  # paper ladder: double|single|half|mixed
    #   (+bf16 variants, +q8/fp8 quantized-operator tiers)
    comm_mode: str = "hier"  # direct | rs | hier | sparse | hier-sparse
    wire: str = "native"  # hier-sparse slow-axis wire: native | q8
    fuse: int = 16  # paper's minibatch size (FFACTOR)
    overlap: bool = True  # Fig. 8 pipelining
    use_ref: bool = False  # oracle instead of Pallas kernel
    interpret: bool | None = None  # Pallas interpret (auto off-TPU)
    staging: str = "fused"  # in-kernel window staging | legacy "gather"
    dma: str = "coalesced"  # run-length window DMAs | "per_row" A/B
    # per-call SMEM budget for the kernel's chunked scalar prefetch
    # (None = kernels.xct_spmm.SMEM_BUDGET)
    smem_budget: int | None = None
    # [deprecated] only the legacy gather path chunks its staging
    # transient; the fused kernel's staging lives in VMEM.
    blocks_per_call: int | None = None

    @classmethod
    def tuned(cls, passport=None, *, tune_dir=None, **overrides):
        """Build a config from a tuning passport (``repro.tune``).

        Resolution: an explicit ``passport`` wins; else the passport
        for THIS machine's hardware fingerprint is looked up under
        ``tune_dir`` (missing or unusable -> stock defaults, never an
        error); ``overrides`` beat passport knobs either way.  Only the
        knobs this dataclass owns are consumed (``precision``,
        ``comm_mode``, ``wire``, ``fuse``, ``dma``) -- partition-level knobs live
        in the passport for ``build_plan`` callers to apply.
        """
        if passport is None and tune_dir is not None:
            from ..tune.passport import resolve_passport

            passport = resolve_passport(tune_dir)
        kw = {}
        if passport is not None:
            for field in ("precision", "comm_mode", "wire", "fuse", "dma"):
                if field in passport.knobs:
                    kw[field] = passport.knobs[field]
        kw.update(overrides)
        return cls(**kw)


class Reconstructor:
    """Distributed iterative reconstruction bound to a mesh topology.

    Args:
      plan: partition plan (``core.partition.build_plan``).
      topology: ``dist.Topology`` naming the communicating (data) and
        batch mesh axes -- ``Topology.from_mesh(mesh, data_axes=...,
        batch_axes=...)``.  The data levels' size product must equal
        ``plan.cfg.n_data``.
      mesh: [deprecated path] JAX mesh; default = 1-device mesh (plan
        must have n_data == 1).  Ignored when ``topology`` is given.
      data_axes, batch_axes: [deprecated] loose axis tuples; pass a
        ``topology`` instead (see docs/dist_api.md).
      cfg: runtime configuration.
    """

    def __init__(
        self,
        plan: Plan,
        mesh=None,
        data_axes=None,
        batch_axes=None,
        cfg: ReconConfig = ReconConfig(),
        abstract: bool = False,
        topology: Topology | None = None,
    ):
        if topology is None:
            if data_axes is not None or batch_axes is not None:
                warnings.warn(
                    "Reconstructor(data_axes=..., batch_axes=...) is "
                    "deprecated; pass topology=Topology.from_mesh(mesh, "
                    "data_axes=..., batch_axes=...) instead",
                    DeprecationWarning,
                    stacklevel=2,
                )
            if mesh is None:
                mesh = jax.make_mesh(
                    (1, 1), ("data", "model"), devices=jax.devices()[:1]
                )
            topology = Topology.from_mesh(
                mesh,
                data_axes=("model",) if data_axes is None
                else tuple(data_axes),
                batch_axes=("data",) if batch_axes is None
                else tuple(batch_axes),
            )
        elif mesh is not None or data_axes is not None \
                or batch_axes is not None:
            raise ValueError(
                "pass either topology= or the deprecated "
                "mesh/data_axes/batch_axes, not both"
            )
        if topology.mesh is None:
            raise ValueError(
                "Reconstructor needs a mesh-bound topology "
                "(Topology.from_mesh)"
            )
        self.plan = plan
        self.topology = topology
        self.mesh = mesh = topology.mesh
        self.cfg = cfg
        if cfg.blocks_per_call is not None:
            warnings.warn(
                "ReconConfig.blocks_per_call is deprecated: the default "
                "fused staging has no HBM transient to chunk; it only "
                'affects the legacy staging="gather" path',
                DeprecationWarning,
                stacklevel=2,
            )
        self.abstract = abstract
        self.data_axes = topology.data_axes
        self.batch_axes = topology.batch_axes
        self.policy = get_policy(cfg.precision)
        if cfg.wire not in ("native", "q8"):
            raise ValueError(
                f"unknown wire {cfg.wire!r}; one of ('native', 'q8')"
            )
        if cfg.wire == "q8" and cfg.comm_mode != "hier-sparse":
            raise ValueError(
                "wire='q8' compresses the hier-sparse slow-axis hop; "
                f"comm_mode={cfg.comm_mode!r} has no such hop (use "
                "comm_mode='hier-sparse' or wire='native')"
            )
        self.comm_plan = topology.plan(cfg.comm_mode)
        if topology.n_data != plan.cfg.n_data:
            raise ValueError(
                f"plan has P_d={plan.cfg.n_data} but data axes "
                f"{self.data_axes} have size {topology.n_data}"
            )
        fast = topology.levels[0].size if topology.levels else 1
        if plan.cfg.socket not in (1, fast):
            warnings.warn(
                f"plan was laid out for socket={plan.cfg.socket} but the "
                f"topology's fast level is {fast}-wide; the hier-sparse "
                "dedup will not see consecutive chunks per socket",
                stacklevel=2,
            )
        self.n_batch = topology.n_batch
        self._rank_rows = None  # lazy inverse row permutation
        self._rank_cols = None
        self._fns: dict = {}
        self._arrays = self._device_arrays()

    # ------------------------------------------------------------------ #
    # data movement helpers (host side)
    # ------------------------------------------------------------------ #
    @property
    def tomo_pad(self) -> int:
        return self.plan.proj.n_cols_pad

    @property
    def sino_pad(self) -> int:
        return self.plan.proj.n_rows_pad

    def pack_tomo(self, x_nat):
        """[n_vox, Y] natural order -> [tomo_pad, Y] stored (device-major
        Hilbert) order; Hilbert chunks land on their owning device slot
        per the plan's socket-aware layout (identity when socket == 1)."""
        n = self.plan.geo.n_vox
        out = np.zeros((self.tomo_pad, x_nat.shape[1]), np.float32)
        pos = self.plan.col_pos
        dst = slice(None, n) if pos is None else pos[:n]
        out[dst] = np.asarray(x_nat)[self.plan.col_perm]
        return out

    def unpack_tomo(self, x_curve):
        g = self.plan.geo
        if self._rank_cols is None:
            pos = self.plan.col_pos
            stored = (
                np.arange(g.n_vox) if pos is None else pos[: g.n_vox]
            )
            rank = np.empty(g.n_vox, np.int64)
            rank[self.plan.col_perm] = stored
            self._rank_cols = rank
        return np.asarray(x_curve)[self._rank_cols]

    def pack_sino(self, y_nat):
        n = self.plan.geo.n_rays
        out = np.zeros((self.sino_pad, y_nat.shape[1]), np.float32)
        pos = self.plan.row_pos
        dst = slice(None, n) if pos is None else pos[:n]
        out[dst] = np.asarray(y_nat)[self.plan.row_perm]
        return out

    def unpack_sino(self, y_curve):
        g = self.plan.geo
        if self._rank_rows is None:
            pos = self.plan.row_pos
            stored = (
                np.arange(g.n_rays) if pos is None else pos[: g.n_rays]
            )
            rank = np.empty(g.n_rays, np.int64)
            rank[self.plan.row_perm] = stored
            self._rank_rows = rank
        return np.asarray(y_curve)[self._rank_rows]

    # ------------------------------------------------------------------ #
    # device arrays
    # ------------------------------------------------------------------ #
    def _device_arrays(self):
        pol = self.policy
        plan = self.plan
        mode = self.cfg.comm_mode
        fast = self.topology.levels[0].size if self.topology.levels else 1
        n_slow = max(1, self.topology.n_data // fast)
        self._socket_rows: dict = {}  # static W per operator (hier-sparse)
        arrs = {}
        for name, op in (("proj", plan.proj), ("back", plan.back)):
            if self.abstract:
                sds = jax.ShapeDtypeStruct
                arrs[f"{name}_inds"] = sds(op.inds.shape, jnp.int16)
                arrs[f"{name}_vals"] = sds(op.vals.shape, pol.vals_dtype)
                if pol.quantized:
                    arrs[f"{name}_vscale"] = sds(
                        op.vals.shape[:3], jnp.int32
                    )
                arrs[f"{name}_winmap"] = sds(op.winmap.shape, jnp.int32)
                buf = op.winmap.shape[-1]
                if op.winsegs is not None and op.segoff is not None:
                    segs_shape = op.winsegs.shape
                    off_shape = op.segoff.shape
                else:
                    # older pickled plans: real winmap, no tables yet
                    segs, off = sort_segments_by_class(
                        winmap_segments(op.winmap), buf
                    )
                    segs_shape, off_shape = segs.shape, off.shape
                arrs[f"{name}_winsegs"] = sds(segs_shape, jnp.int32)
                arrs[f"{name}_segoff"] = sds(off_shape, jnp.int32)
                arrs[f"{name}_row_map"] = sds(
                    op.row_map.shape, jnp.int32
                )
                p = op.inds.shape[0]
                if mode == "sparse":
                    v = getattr(op, "est_v", 8)
                    arrs[f"{name}_send"] = sds((p, p, v), jnp.int32)
                    arrs[f"{name}_recv"] = sds((p, p, v), jnp.int32)
                elif mode == "hier-sparse":
                    w, v2 = estimate_hier_sparse(op, fast, n_slow)
                    self._socket_rows[name] = w
                    arrs[f"{name}_smap"] = sds(
                        (p, op.flat_rows), jnp.int32
                    )
                    arrs[f"{name}_send"] = sds((p, n_slow, v2), jnp.int32)
                    arrs[f"{name}_recv"] = sds((p, n_slow, v2), jnp.int32)
                continue
            arrs[f"{name}_inds"] = op.inds
            if pol.quantized:
                # pack once at bind time: int8/fp8 values + per-(block,
                # stage) power-of-two dequant exponents the kernel
                # applies inline (core.precision.quantize_block_vals)
                q, exp = quantize_block_vals(op.vals, pol.vals_dtype)
                arrs[f"{name}_vals"] = np.asarray(q)
                arrs[f"{name}_vscale"] = np.asarray(exp)
            else:
                arrs[f"{name}_vals"] = op.vals.astype(pol.storage)
            arrs[f"{name}_winmap"] = op.winmap
            if op.winsegs is not None and op.segoff is not None:
                segs, off = op.winsegs, op.segoff
            else:  # older pickled plans: build both tables now
                segs, off = sort_segments_by_class(
                    winmap_segments(op.winmap), op.winmap.shape[-1]
                )
            arrs[f"{name}_winsegs"] = segs
            arrs[f"{name}_segoff"] = off
            arrs[f"{name}_row_map"] = op.row_map
            if mode == "sparse":
                send, recv, _ = build_sparse_exchange(op)
                arrs[f"{name}_send"] = send
                arrs[f"{name}_recv"] = recv
            elif mode == "hier-sparse":
                smap, send, recv, w, _ = build_hier_sparse_exchange(
                    op, fast
                )
                self._socket_rows[name] = w
                arrs[f"{name}_smap"] = smap
                arrs[f"{name}_send"] = send
                arrs[f"{name}_recv"] = recv
        return arrs

    def lower_cg(self, y_slices: int, iters: int):
        """Lower+compile the CG step with abstract inputs (dry-run)."""
        sds = jax.ShapeDtypeStruct
        y = sds((self.sino_pad, y_slices), jnp.float32)
        x0 = sds((self.tomo_pad, y_slices), jnp.float32)
        fn = self._get_fn("cg", iters)
        lowered = fn.lower(self._arrays, y, x0)
        return lowered, lowered.compile()

    # ------------------------------------------------------------------ #
    # per-device compute
    # ------------------------------------------------------------------ #
    def _make_ops(self, a):
        """Closures (project, backproject, dot_rows) for shard-local data."""
        cfg, pol = self.cfg, self.policy
        daxes = self.data_axes
        plan = self.plan

        def one_operator(prefix, rows_out):
            inds = a[f"{prefix}_inds"][0]
            vals = a[f"{prefix}_vals"][0]
            vscale = (
                a[f"{prefix}_vscale"][0] if pol.quantized else None
            )
            winmap = a[f"{prefix}_winmap"][0]
            winsegs = a[f"{prefix}_winsegs"][0]
            segoff = a[f"{prefix}_segoff"][0]
            row_map = a[f"{prefix}_row_map"][0]
            n_rows_pad = rows_out * math.prod(
                self.mesh.shape[x] for x in daxes
            )

            def kernel(x_f):
                return apply_operator(
                    inds,
                    vals,
                    winmap,
                    x_f,
                    storage_dtype=pol.storage,
                    compute_dtype=pol.compute,
                    use_ref=cfg.use_ref,
                    interpret=cfg.interpret,
                    staging=cfg.staging,
                    dma=cfg.dma,
                    winsegs=winsegs,
                    segoff=segoff,
                    smem_budget=cfg.smem_budget,
                    blocks_per_call=cfg.blocks_per_call,
                    scales=vscale,
                )

            comm_plan = self.comm_plan

            def reduce(band):
                bandc, inv = qcast(
                    band,
                    pol.comm,
                    adaptive=pol.adaptive,
                    axis_name=daxes,
                )
                if cfg.comm_mode in ("sparse", "hier-sparse"):
                    hier = cfg.comm_mode == "hier-sparse"
                    chunk = sparse_exchange(
                        bandc,
                        a[f"{prefix}_send"][0],
                        a[f"{prefix}_recv"][0],
                        self.topology,
                        rows_out,
                        socket_map=(
                            a[f"{prefix}_smap"][0] if hier else None
                        ),
                        socket_rows=(
                            self._socket_rows[prefix] if hier else None
                        ),
                        wire=cfg.wire,
                    )
                else:
                    # scatter-ADD: split rows (virtual-row packing) may
                    # map several band slots onto one global row
                    idx = row_map.reshape(-1)
                    full = (
                        jnp.zeros((n_rows_pad, band.shape[-1]), bandc.dtype)
                        .at[idx]
                        .add(bandc, mode="drop")
                    )
                    chunk = comm_plan.reduce_partials(full)
                return chunk.astype(jnp.float32) * inv

            narrow = (
                pol.storage_bytes < 4
                or jnp.dtype(pol.compute).itemsize < 4
            )

            def apply(x_all):
                inv = None
                if narrow:
                    # Paper III-C1: renormalize the evolving iterate per
                    # slice before every (back)projection so the fp16
                    # accumulation never under/overflows.
                    s = adaptive_scale_cols(x_all, 1.0, daxes)
                    x_all = (
                        x_all.astype(jnp.float32) * s
                    ).astype(pol.storage)
                    inv = 1.0 / s
                out = pipelined_apply(
                    kernel, reduce, x_all, cfg.fuse, overlap=cfg.overlap
                )
                return out if inv is None else out * inv

            return apply

        project = one_operator("proj", plan.proj.rows_per_dev)
        backproject = one_operator("back", plan.back.rows_per_dev)

        def dot_rows(u, v):
            # Scalar reductions always in f32: a half-mode dot over 1e6+
            # entries overflows f16's 65504 range (the paper's half mode
            # relies on its normalized beamline data; we normalize inputs
            # too -- see reconstruct() -- and keep the reduction wide).
            s = jnp.sum(
                u.astype(jnp.float32) * v.astype(jnp.float32), axis=0
            )
            return jax.lax.psum(s, daxes)

        return project, backproject, dot_rows

    # ------------------------------------------------------------------ #
    # jitted entry points
    # ------------------------------------------------------------------ #
    def _specs(self):
        d = P(self.data_axes)
        op_names = ["inds", "vals", "winmap", "winsegs", "segoff",
                    "row_map"]
        if self.policy.quantized:
            op_names += ["vscale"]
        if self.cfg.comm_mode == "sparse":
            op_names += ["send", "recv"]
        elif self.cfg.comm_mode == "hier-sparse":
            op_names += ["send", "recv", "smap"]
        arr_specs = {
            f"{pre}_{nm}": d for pre in ("proj", "back") for nm in op_names
        }
        vec = P(self.data_axes, self.batch_axes or None)
        return arr_specs, vec

    def _get_fn(self, kind: str, iters: int = 0):
        key = (kind, iters)
        if key in self._fns:
            return self._fns[key]
        arr_specs, vec = self._specs()
        pol = self.policy

        if kind in ("project", "backproject"):

            def fn(a, x):
                proj, back, _ = self._make_ops(a)
                op = proj if kind == "project" else back
                return op(x.astype(pol.storage)).astype(jnp.float32)

            out_specs = vec
        elif kind == "cg":

            def fn(a, y, x0):
                proj, back, dot = self._make_ops(a)
                x, res = cgnr(
                    proj,
                    back,
                    y,
                    x0,
                    iters,
                    dot,
                    compute_dtype=pol.compute,
                    storage_dtype=pol.storage,
                )
                return x.astype(jnp.float32), res.astype(jnp.float32)

            out_specs = (vec, P(None, self.batch_axes or None))
        else:
            raise ValueError(kind)

        in_specs = (arr_specs,) + (
            (vec,) if kind != "cg" else (vec, vec)
        )
        mapped = jax.shard_map(
            fn,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
        jitted = jax.jit(mapped)
        self._fns[key] = jitted
        return jitted

    # ------------------------------------------------------------------ #
    # public API (natural-order numpy in/out)
    # ------------------------------------------------------------------ #
    def _check_slices(self, y: int):
        per = self.n_batch * self.cfg.fuse
        if y % per:
            raise ValueError(
                f"slice count {y} must be a multiple of batch x fuse = {per}"
            )

    def project(self, x_nat):
        """[n_vox, Y] -> [n_rays, Y] forward projection."""
        self._check_slices(x_nat.shape[1])
        out = self._get_fn("project")(self._arrays, self.pack_tomo(x_nat))
        return self.unpack_sino(out)

    def backproject(self, y_nat):
        """[n_rays, Y] -> [n_vox, Y] back projection (A^T)."""
        self._check_slices(y_nat.shape[1])
        out = self._get_fn("backproject")(
            self._arrays, self.pack_sino(y_nat)
        )
        return self.unpack_tomo(out)

    def stage_sino(self, sino_nat) -> StagedSlab:
        """Pack + normalize + upload one sinogram slab (host -> device).

        The host->device half of :meth:`reconstruct`, split out so a
        prefetch thread can run it for slab ``i+1`` while slab ``i``
        solves (``stream.driver`` wires this through
        ``scheduler.Prefetcher``'s ``stage=``).  Blocks until the
        transfer lands so the caller's timing is honest.
        """
        self._check_slices(sino_nat.shape[1])
        with obs_span("recon/stage", slices=int(sino_nat.shape[1])):
            y = self.pack_sino(sino_nat)
            m = np.abs(y).max(axis=0)
            # target 1.0: keeps every CG vector (and the fp16 CG
            # scalars) O(n * K) at most, inside half range for any
            # practical geometry
            scale = np.exp2(
                np.round(np.log2(1.0 / np.maximum(m, 1e-30)))
            ).astype(np.float32)
            _, vec = self._specs()
            y_dev = jax.device_put(
                y * scale, jax.sharding.NamedSharding(self.mesh, vec)
            )
            jax.block_until_ready(y_dev)
        return StagedSlab(
            y=y_dev, scale=scale, n_slices=int(sino_nat.shape[1])
        )

    def reconstruct(self, sino_nat, iters: int = 30, x0_nat=None):
        """CGNR solve; returns ``(x [n_vox, Y], resnorms [iters, Y])``.

        Inputs are adaptively normalized per slice (power-of-two factor
        steering max|y| to ~256, paper Sec. III-C1) so narrow-precision
        iterates stay in range; the solution scales back exactly.
        ``sino_nat`` may be a pre-staged :class:`StagedSlab` (see
        :meth:`stage_sino`); the math is identical either way.

        Raises :class:`~repro.resil.errors.NonFiniteSolveError` when
        the solution contains NaN/Inf (a blown-up narrow-precision
        solve) -- the streaming driver's retry/escalate/quarantine
        hook.
        """
        staged = (
            sino_nat
            if isinstance(sino_nat, StagedSlab)
            else self.stage_sino(sino_nat)
        )
        scale = staged.scale
        x0 = (
            self.pack_tomo(x0_nat) * scale
            if x0_nat is not None
            else np.zeros((self.tomo_pad, staged.n_slices), np.float32)
        )
        with obs_span(
            "recon/solve", iters=iters, slices=staged.n_slices
        ) as sp:
            x, res = self._get_fn("cg", iters)(self._arrays, staged.y, x0)
            sp.fence(x)  # async dispatch must not end the span early
        self._emit_exchange(iters, staged.n_slices)
        x_nat = self.unpack_tomo(x) / scale
        # the resilience guard: a narrow-precision solve that blew up
        # (or an injected nonfinite fault) surfaces as a typed error the
        # streaming driver can retry / escalate one precision rung /
        # quarantine, instead of NaNs landing silently in the volume
        x_nat = inject.mutate(
            "recon/solve", x_nat, ctx={"precision": self.cfg.precision}
        )
        if not np.isfinite(x_nat).all():
            n_bad = int(x_nat.size - np.isfinite(x_nat).sum())
            raise NonFiniteSolveError(
                f"solve produced {n_bad} non-finite value(s) over "
                f"{staged.n_slices} slices "
                f"(precision={self.cfg.precision})"
            )
        return x_nat, np.asarray(res) / scale

    def _emit_exchange(self, iters: int, n_slices: int):
        """Annotate a finished solve with its modeled wire traffic.

        The exchanges themselves run inside the jitted shard_map --
        host spans cannot time them -- so when tracing is on we emit a
        ``recon/exchange`` instant carrying the *modeled* per-link
        bytes of the whole solve (``launch.xct_perf.comm_volume`` per
        fused minibatch, x ``iters + 1`` operator applications, the
        same pricing the autotuner and ``obs.drift`` use) and bump the
        ``comm_bytes_total{link=}`` / ``dma_issues_total`` counters.
        """
        tracer = obs_trace.get_tracer()
        if not tracer.enabled:
            return
        per_mini = getattr(self, "_obs_traffic", None)
        if per_mini is None:
            from ..kernels.traffic import (
                op_segments_per_stage,
                spmm_traffic,
            )
            from ..launch.xct_perf import comm_volume

            wire = comm_volume(
                self.plan, self.cfg.comm_mode, self.cfg.fuse,
                self.policy.comm_bytes, self.topology,
                wire=self.cfg.wire,
            )
            issues = 0.0
            for op in (self.plan.proj, self.plan.back):
                _, b, s, r, k = op.inds.shape
                issues += spmm_traffic(
                    b, s, r, k, op.winmap.shape[-1], self.cfg.fuse,
                    storage_bytes=self.policy.storage_bytes,
                    vals_bytes=self.policy.vals_bytes,
                    staging=self.cfg.staging,
                    dma=self.cfg.dma,
                    segments_per_stage=op_segments_per_stage(op),
                )["dma_issues"]
            per_mini = self._obs_traffic = {
                "ici": wire["ici"], "dci": wire["dci"],
                "dma_issues": issues,
            }
        minis = n_slices // (self.n_batch * self.cfg.fuse)
        apps = iters + 1  # CGNR: initial A/A^T pair + one per iteration
        scale = minis * apps
        tracer.instant(
            "recon/exchange",
            ici_bytes=per_mini["ici"] * scale,
            dci_bytes=per_mini["dci"] * scale,
            iters=iters,
            slices=n_slices,
        )
        obs_metrics.inc(
            "comm_bytes_total", per_mini["ici"] * scale, link="ici"
        )
        obs_metrics.inc(
            "comm_bytes_total", per_mini["dci"] * scale, link="dci"
        )
        obs_metrics.inc(
            "dma_issues_total", per_mini["dma_issues"] * scale, op="spmm"
        )
