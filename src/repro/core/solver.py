"""Iterative solvers for ``argmin_x ||y - Ax||^2`` (paper Sec. II-A).

CGNR (conjugate gradient on the normal equations) with a fixed iteration
count, as in the paper's evaluation (30 CG iterations = 30 projections + 31
backprojections).  The solver is *distribution-agnostic*: it sees two linear
maps and two dot products; `core.recon` closes them over the sharded
operators and collectives, so the same code runs single-device tests and
512-chip dry-runs.

Per-slice scalars: slices of the volume are independent least-squares
problems sharing ``A``; alpha/beta are computed per fused slice (shape
``[F]``), which both vectorizes trivially and never couples slices.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["cgnr"]


def cgnr(
    apply_a: Callable,
    apply_at: Callable,
    y,
    x0,
    iters: int,
    dot_rows: Callable,
    *,
    compute_dtype=jnp.float32,
    storage_dtype=None,
):
    """CGNR with static iteration count via ``lax.scan``.

    Args:
      apply_a: x -> A x (handles sharding + precision internally).
      apply_at: r -> A^T r.
      y: measurement slab(s), last dim = slices.
      x0: initial iterate.
      iters: CG iterations (paper uses 30; convergence bench varies this).
      dot_rows: (u, v) -> per-slice dot product reduced over rows (and over
        data-parallel shards by the caller), returning shape ``[F]``.
      compute_dtype: scalar/update arithmetic dtype.
      storage_dtype: dtype the iterate vectors are *kept* in between
        iterations (the paper stores state in half for mixed mode; defaults
        to ``compute_dtype``).

    Returns:
      (x, resnorms) -- resnorms has shape ``[iters, F]`` with the per-slice
      residual norm ``||y - Ax||`` after each iteration.
    """
    storage_dtype = storage_dtype or compute_dtype
    eps = jnp.asarray(jnp.finfo(compute_dtype).tiny, compute_dtype)

    def st(v):
        return v.astype(storage_dtype)

    def co(v):
        return v.astype(compute_dtype)

    r0 = co(y) - co(apply_a(st(x0)))
    s0 = co(apply_at(st(r0)))
    gamma0 = dot_rows(s0, s0)

    def body(carry, _):
        x, r, p, gamma = carry
        q = co(apply_a(st(p)))
        # CG scalars stay f32 (dot_rows reduces wide); cast at the update
        alpha = (gamma / jnp.maximum(dot_rows(q, q), eps)).astype(
            compute_dtype
        )
        x = co(x) + alpha[None, :] * co(p)
        r = r - alpha[None, :] * q
        s = co(apply_at(st(r)))
        gamma_new = dot_rows(s, s)
        beta = (gamma_new / jnp.maximum(gamma, eps)).astype(compute_dtype)
        p = s + beta[None, :] * co(p)
        resnorm = jnp.sqrt(dot_rows(r, r))
        return (st(x), r, st(p), gamma_new), resnorm

    carry0 = (st(x0), r0, st(s0), gamma0)
    (x, _, _, _), resnorms = jax.lax.scan(
        body, carry0, None, length=iters
    )
    return x, resnorms
