"""Batch processing pipeline: minibatch comm/compute overlap (Sec. III-E).

One (back)projection over an I/O batch of ``Y`` slices is processed as
``Y / F`` minibatches of ``F`` fused slices.  The paper overlaps the global
(MPI) reduction of minibatch ``i`` with the local work of minibatch ``i+1``
(Fig. 8).  We express the same schedule as a software-pipelined
``lax.scan``: each step issues the kernel for chunk ``i`` *and* the
reduction for the carried chunk ``i-1``; the two have no data dependency
inside the step, so XLA's async collectives / latency-hiding scheduler can
run them concurrently on TPU.

``overlap=False`` serializes the two phases per step (the paper's
measurement mode, Fig. 10-11, where communications are synchronized to be
timed).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["pipelined_apply"]


def pipelined_apply(
    kernel_fn: Callable,
    reduce_fn: Callable,
    x_all,
    fuse: int,
    *,
    overlap: bool = True,
):
    """Apply ``reduce_fn(kernel_fn(chunk))`` over slice-minibatches.

    Args:
      kernel_fn: [C, F] slab -> [band_rows, F] partial (local SpMM).
      reduce_fn: [band_rows, F] partial -> [rows_out, F] owned chunk
        (the communication phase).
      x_all: [C, Y] input slab, ``Y = n_mini * fuse``.
      fuse: minibatch size F (the paper's FFACTOR; 16 in their runs).
      overlap: software-pipeline the two phases (Fig. 8) or serialize.

    Returns:
      [rows_out, Y] reduced output for the whole I/O batch.
    """
    c, y = x_all.shape
    assert y % fuse == 0, (y, fuse)
    n_mini = y // fuse
    chunks = x_all.reshape(c, n_mini, fuse).transpose(1, 0, 2)  # [n,C,F]

    if not overlap or n_mini == 1:
        def step(_, xc):
            return None, reduce_fn(kernel_fn(xc))
        _, outs = jax.lax.scan(step, None, chunks)
    else:
        first_band = kernel_fn(chunks[0])

        def step(pending, xc):
            # kernel(i) and reduce(i-1) are independent -> overlappable.
            band = kernel_fn(xc)
            out_prev = reduce_fn(pending)
            return band, out_prev

        last_band, outs_head = jax.lax.scan(step, first_band, chunks[1:])
        outs_tail = reduce_fn(last_band)[None]
        outs = (
            jnp.concatenate([outs_head, outs_tail], axis=0)
            if n_mini > 1
            else outs_tail
        )
    # [n, rows_out, F] -> [rows_out, Y]
    return outs.transpose(1, 0, 2).reshape(outs.shape[1], y)
