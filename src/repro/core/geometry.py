"""Parallel-beam XCT geometry: vectorized Siddon ray tracing.

Builds the sparse system matrix ``A`` (rays x voxels) whose entry (r, v) is
the exact intersection length of ray ``r`` with voxel ``v`` (Siddon [9]).
Parallel-beam geometry means every slice along the rotation axis shares the
*same* ``A`` -- the paper's central 3D observation (Sec. II-B): rays
``u_{*,j}`` trace the same voxels in all slices, so ``A`` is built once per
volume and *fused* across slices (SpMV -> SpMM).

The build is host-side NumPy (this is MemXCT's "memoization": ``A`` is
computed once and reused for every projection/backprojection of every
iteration), vectorized over detector channels and chunked over angles so the
working set stays bounded.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = ["XCTGeometry", "build_system_matrix", "estimate_nnz_per_ray"]


@dataclasses.dataclass(frozen=True)
class XCTGeometry:
    """Scan geometry for one slice (shared by all slices of the volume).

    Attributes:
      n: image is ``n x n`` voxels.
      n_angles: number of projection angles ``K`` spread uniformly in [0, pi).
      n_det: detector channels per projection row (defaults to ``n``).
      vox: voxel side length.  The paper's *adaptive normalization*
        (Sec. III-C1) artificially inflates the voxel size so fp16 lengths
        do not underflow; ``precision.choose_voxel_scale`` picks it.
    """

    n: int
    n_angles: int
    n_det: int | None = None
    vox: float = 1.0

    @property
    def num_det(self) -> int:
        return self.n_det if self.n_det is not None else self.n

    @property
    def n_rays(self) -> int:
        return self.n_angles * self.num_det

    @property
    def n_vox(self) -> int:
        return self.n * self.n


def _siddon_one_angle(geo: XCTGeometry, theta: float) -> tuple[np.ndarray, ...]:
    """All rays of one projection angle.  Returns COO (chan, col, len)."""
    n, vox = geo.n, geo.vox
    c = geo.num_det
    half = n * vox / 2.0
    planes = -half + vox * np.arange(n + 1)  # grid-line coordinates

    ux, uy = np.cos(theta), np.sin(theta)  # propagation direction
    ex, ey = -np.sin(theta), np.cos(theta)  # detector axis
    t = (np.arange(c) - (c - 1) / 2.0) * vox  # channel offsets
    # Ray origin far outside the grid; |u| = 1 so alpha == arc length.
    L = 2.0 * half * 2.0
    p0x = t * ex - L * ux
    p0y = t * ey - L * uy

    eps = 1e-12
    with np.errstate(divide="ignore", invalid="ignore"):
        ax = (planes[None, :] - p0x[:, None]) / ux if abs(ux) > eps else None
        ay = (planes[None, :] - p0y[:, None]) / uy if abs(uy) > eps else None

    # Entry/exit of the bounding box per ray.
    lo = np.full(c, -np.inf)
    hi = np.full(c, np.inf)
    for a in (ax, ay):
        if a is not None:
            lo = np.maximum(lo, np.minimum(a[:, 0], a[:, -1]))
            hi = np.minimum(hi, np.maximum(a[:, 0], a[:, -1]))
    # Rays parallel to an axis must still lie inside that axis' extent.
    if ax is None:
        inside = (p0x >= planes[0]) & (p0x <= planes[-1])
        hi = np.where(inside, hi, lo)
    if ay is None:
        inside = (p0y >= planes[0]) & (p0y <= planes[-1])
        hi = np.where(inside, hi, lo)

    parts = [a for a in (ax, ay) if a is not None]
    alphas = np.concatenate(parts + [lo[:, None], hi[:, None]], axis=1)
    alphas = np.clip(alphas, lo[:, None], hi[:, None])
    alphas.sort(axis=1)

    seg = np.diff(alphas, axis=1)  # intersection lengths
    mid = 0.5 * (alphas[:, 1:] + alphas[:, :-1])
    px = p0x[:, None] + mid * ux
    py = p0y[:, None] + mid * uy
    ix = np.floor((px + half) / vox).astype(np.int64)
    iy = np.floor((py + half) / vox).astype(np.int64)

    valid = (seg > 1e-9 * vox) & (ix >= 0) & (ix < n) & (iy >= 0) & (iy < n)
    chan = np.broadcast_to(np.arange(c)[:, None], seg.shape)[valid]
    col = (iy * n + ix)[valid]
    return chan, col, seg[valid]


def build_system_matrix(geo: XCTGeometry, dtype=np.float32) -> sp.csr_matrix:
    """Exact Siddon system matrix ``A`` of shape (K * n_det, n * n)."""
    rows, cols, vals = [], [], []
    thetas = np.pi * np.arange(geo.n_angles) / geo.n_angles
    for k, theta in enumerate(thetas):
        chan, col, seg = _siddon_one_angle(geo, theta)
        rows.append(chan + k * geo.num_det)
        cols.append(col)
        vals.append(seg)
    coo = sp.coo_matrix(
        (
            np.concatenate(vals).astype(dtype),
            (np.concatenate(rows), np.concatenate(cols)),
        ),
        shape=(geo.n_rays, geo.n_vox),
    )
    csr = coo.tocsr()
    csr.sum_duplicates()
    return csr


def estimate_nnz_per_ray(n: int) -> float:
    """Analytic mean voxels-per-ray for dry-run shape derivation.

    A ray at angle theta crossing the full grid visits ~ n*(|cos|+|sin|)
    voxels; averaging over theta in [0, pi) and over channels (not all rays
    cross the full width) gives ~ (4/pi) * n * (pi/4) = n.  We use the
    empirically tight 1.195 * n (measured over n in [32, 512]).
    """
    return 1.195 * n
