"""Mixed-precision policies + adaptive normalization (paper Sec. III-C).

The paper stores and communicates in half precision and computes in single
precision, guarding fp16's narrow range with *adaptive normalization*: the
(de)normalization factor follows the max-norm of the evolving iterate so
casts neither overflow nor underflow.

On TPU the natural half type is bf16 (wide exponent -> normalization rarely
binds) but fp16 is retained both for paper fidelity and because it is the
denser VREG type on some targets.  The four policies mirror the paper's
double / single / half / mixed ladder; ``double`` uses f64 (available on the
CPU validation platform; on TPU deployments it maps to f32 -- documented in
DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "Precision",
    "POLICIES",
    "get_policy",
    "adaptive_scale",
    "adaptive_scale_cols",
    "qcast",
]


@dataclasses.dataclass(frozen=True)
class Precision:
    """A storage/compute/communication dtype triple.

    Attributes:
      storage: dtype of resident vectors and of the sparse-matrix values
        (the paper's 2-byte ``len`` when half/mixed).
      compute: FMA/accumulation dtype inside kernels.
      comm: wire dtype for partial-data reductions.
      adaptive: apply max-norm power-of-two rescaling around narrow casts.
    """

    name: str
    storage: jnp.dtype
    compute: jnp.dtype
    comm: jnp.dtype
    adaptive: bool = False

    @property
    def storage_bytes(self) -> int:
        return jnp.dtype(self.storage).itemsize

    @property
    def comm_bytes(self) -> int:
        return jnp.dtype(self.comm).itemsize


POLICIES = {
    "double": Precision("double", jnp.float64, jnp.float64, jnp.float64),
    "single": Precision("single", jnp.float32, jnp.float32, jnp.float32),
    "half": Precision("half", jnp.float16, jnp.float16, jnp.float16),
    "mixed": Precision(
        "mixed", jnp.float16, jnp.float32, jnp.float16, adaptive=True
    ),
    # TPU-native variants (beyond-paper; bf16 wire format).
    "bf16": Precision("bf16", jnp.bfloat16, jnp.bfloat16, jnp.bfloat16),
    "mixed_bf16": Precision(
        "mixed_bf16", jnp.bfloat16, jnp.float32, jnp.bfloat16, adaptive=True
    ),
}


def get_policy(name: str) -> Precision:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown precision {name!r}; one of {sorted(POLICIES)}"
        ) from None


def adaptive_scale(x, target: float = 256.0, axis_name=None):
    """Power-of-two factor steering ``max|x|`` to ``target`` (Sec. III-C1).

    Power-of-two so the scaling itself is lossless in any binary float
    format.  When ``axis_name`` is given (inside shard_map) the max-norm is
    taken over the named axes so every shard applies the *same* factor.
    Returns the scale ``s`` such that ``x * s`` is cast-safe; apply ``1/s``
    after the round trip.
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)))
    if axis_name is not None:
        m = jax.lax.pmax(m, axis_name)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    exp = jnp.round(jnp.log2(target / m))
    # Clamp so the factor itself stays representable far from inf/0;
    # ldexp(1, e) = 2^e bit-exactly (exp2 would round in f32).
    exp = jnp.clip(exp, -100.0, 100.0).astype(jnp.int32)
    return jnp.ldexp(jnp.float32(1.0), exp)


def adaptive_scale_cols(x, target: float = 1.0, axis_name=None):
    """Per-column (per-slice) power-of-two normalization factors.

    The paper's III-C1 applied to the evolving CG vectors: each fused
    slice gets its own factor (slices are independent problems with
    independent dynamic ranges).  Returns ``s`` with shape ``[F]``.
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0)
    if axis_name is not None:
        m = jax.lax.pmax(m, axis_name)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    exp = jnp.clip(jnp.round(jnp.log2(target / m)), -100, 100)
    return jnp.ldexp(jnp.ones_like(m), exp.astype(jnp.int32))


def qcast(x, dtype, *, adaptive: bool = False, target: float = 256.0,
          axis_name=None):
    """Cast with optional adaptive normalization.

    Returns ``(x_cast, inv_scale)``; multiply by ``inv_scale`` after the
    matching upcast.  For wide targets (f32/f64) this is a plain cast.
    """
    if jnp.dtype(dtype).itemsize >= 4 or not adaptive:
        return x.astype(dtype), jnp.float32(1.0)
    s = adaptive_scale(x, target=target, axis_name=axis_name)
    return (x.astype(jnp.float32) * s).astype(dtype), 1.0 / s
