"""Mixed-precision policies + adaptive normalization (paper Sec. III-C).

The paper stores and communicates in half precision and computes in single
precision, guarding fp16's narrow range with *adaptive normalization*: the
(de)normalization factor follows the max-norm of the evolving iterate so
casts neither overflow nor underflow.

On TPU the natural half type is bf16 (wide exponent -> normalization rarely
binds) but fp16 is retained both for paper fidelity and because it is the
denser VREG type on some targets.  The four policies mirror the paper's
double / single / half / mixed ladder; ``double`` uses f64 (available on the
CPU validation platform; on TPU deployments it maps to f32 -- documented in
DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "Precision",
    "POLICIES",
    "ALIASES",
    "get_policy",
    "adaptive_scale",
    "adaptive_scale_cols",
    "qcast",
    "quantize_block_vals",
    "dequantize_block_vals",
]


@dataclasses.dataclass(frozen=True)
class Precision:
    """A storage/compute/communication dtype triple (plus operator vals).

    Attributes:
      storage: dtype of resident vectors and of the staged input windows
        (the paper's 2-byte packing when half/mixed).
      compute: FMA/accumulation dtype inside kernels.
      comm: wire dtype for partial-data reductions.
      adaptive: apply max-norm power-of-two rescaling around narrow casts.
      vals: dtype of the packed sparse-matrix *values*, decoupled from
        ``storage`` so the operator can drop below the vector width
        (int8 / fp8 with per-block scales -- the quantized ladder rung).
        ``None`` means "same as storage" (every pre-quantization policy).
    """

    name: str
    storage: jnp.dtype
    compute: jnp.dtype
    comm: jnp.dtype
    adaptive: bool = False
    vals: object = None

    @property
    def storage_bytes(self) -> int:
        return jnp.dtype(self.storage).itemsize

    @property
    def comm_bytes(self) -> int:
        return jnp.dtype(self.comm).itemsize

    @property
    def vals_dtype(self):
        """Operator value dtype (defaults to the vector storage dtype)."""
        return self.storage if self.vals is None else self.vals

    @property
    def vals_bytes(self) -> int:
        return jnp.dtype(self.vals_dtype).itemsize

    @property
    def quantized(self) -> bool:
        """True when operator vals carry per-block scales (1-byte tier)."""
        return self.vals is not None


def _fp8_dtype():
    """fp8-e4m3 where this jax build ships it (TPU-era numpy/ml_dtypes);
    ``None`` gates the policy off cleanly elsewhere."""
    return getattr(jnp, "float8_e4m3fn", None)


POLICIES = {
    "double": Precision("double", jnp.float64, jnp.float64, jnp.float64),
    "single": Precision("single", jnp.float32, jnp.float32, jnp.float32),
    "half": Precision("half", jnp.float16, jnp.float16, jnp.float16),
    "mixed": Precision(
        "mixed", jnp.float16, jnp.float32, jnp.float16, adaptive=True
    ),
    # TPU-native variants (beyond-paper; bf16 wire format).
    "bf16": Precision("bf16", jnp.bfloat16, jnp.bfloat16, jnp.bfloat16),
    "mixed_bf16": Precision(
        "mixed_bf16", jnp.bfloat16, jnp.float32, jnp.bfloat16, adaptive=True
    ),
    # Quantized operator tier: int8 vals + per-block power-of-two scales
    # (dequantized inline in the kernel's FMA loop); vectors/wire stay at
    # the mixed policy's f16, compute stays f32.
    "q8": Precision(
        "q8", jnp.float16, jnp.float32, jnp.float16, adaptive=True,
        vals=jnp.int8,
    ),
}
if _fp8_dtype() is not None:
    POLICIES["fp8"] = Precision(
        "fp8", jnp.float16, jnp.float32, jnp.float16, adaptive=True,
        vals=_fp8_dtype(),
    )

# Spelling conveniences: the dtype names people type first.
ALIASES = {
    "f32": "single",
    "f64": "double",
    "f16": "half",
    "int8": "q8",
}


def get_policy(name: str) -> Precision:
    key = ALIASES.get(name, name)
    try:
        return POLICIES[key]
    except KeyError:
        raise KeyError(
            f"unknown precision {name!r}; one of {sorted(POLICIES)} "
            f"(aliases: {', '.join(f'{a}->{b}' for a, b in sorted(ALIASES.items()))})"
        ) from None


def adaptive_scale(x, target: float = 256.0, axis_name=None):
    """Power-of-two factor steering ``max|x|`` to ``target`` (Sec. III-C1).

    Power-of-two so the scaling itself is lossless in any binary float
    format.  When ``axis_name`` is given (inside shard_map) the max-norm is
    taken over the named axes so every shard applies the *same* factor.
    Returns the scale ``s`` such that ``x * s`` is cast-safe; apply ``1/s``
    after the round trip.
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)))
    if axis_name is not None:
        m = jax.lax.pmax(m, axis_name)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    exp = jnp.round(jnp.log2(target / m))
    # Clamp so the factor itself stays representable far from inf/0;
    # ldexp(1, e) = 2^e bit-exactly (exp2 would round in f32).
    exp = jnp.clip(exp, -100.0, 100.0).astype(jnp.int32)
    return jnp.ldexp(jnp.float32(1.0), exp)


def adaptive_scale_cols(x, target: float = 1.0, axis_name=None):
    """Per-column (per-slice) power-of-two normalization factors.

    The paper's III-C1 applied to the evolving CG vectors: each fused
    slice gets its own factor (slices are independent problems with
    independent dynamic ranges).  Returns ``s`` with shape ``[F]``.
    """
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0)
    if axis_name is not None:
        m = jax.lax.pmax(m, axis_name)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    exp = jnp.clip(jnp.round(jnp.log2(target / m)), -100, 100)
    return jnp.ldexp(jnp.ones_like(m), exp.astype(jnp.int32))


def qcast(x, dtype, *, adaptive: bool = False, target: float = 256.0,
          axis_name=None):
    """Cast with optional adaptive normalization.

    Returns ``(x_cast, inv_scale)``; multiply by ``inv_scale`` after the
    matching upcast.  For wide targets (f32/f64) this is a plain cast.
    """
    if jnp.dtype(dtype).itemsize >= 4 or not adaptive:
        return x.astype(dtype), jnp.float32(1.0)
    s = adaptive_scale(x, target=target, axis_name=axis_name)
    return (x.astype(jnp.float32) * s).astype(dtype), 1.0 / s


def _quant_target(dtype) -> float:
    """Max-|value| the quantized grid should land on: int8's symmetric
    127, or fp8-e4m3's 240 (max finite 448, with headroom so the
    power-of-two rounding of the scale can overshoot by 2x safely)."""
    return 127.0 if jnp.dtype(dtype).kind == "i" else 240.0


def quantize_block_vals(vals, dtype):
    """Pack operator values into ``dtype`` with per-block scales.

    One power-of-two scale per (row-block, stage) -- computed with the
    same max-norm machinery as :func:`adaptive_scale_cols`, each block
    treated as one column -- steers that block's max |value| onto the
    narrow grid.  Power-of-two scales make the (de)scaling itself
    lossless, so the only error is the grid rounding.

    Args:
      vals: ``[..., R, K]`` float lengths (the shards use
        ``[P, B, S, R, K]``; every leading index is its own block).
      dtype: ``jnp.int8`` or the fp8-e4m3 dtype.

    Returns:
      ``(q, exp)``: ``q`` the packed ``[..., R, K]`` values and ``exp``
      the int32 ``[...]`` *dequantization* exponents -- the original
      values are approximated by ``q * 2.0**exp`` (see
      :func:`dequantize_block_vals`; the kernel applies the same factor
      inline in its FMA loop).
    """
    dt = jnp.dtype(dtype)
    lead = vals.shape[:-2]
    flat = jnp.asarray(vals, jnp.float32).reshape(
        max(1, math.prod(lead)), -1
    )
    # Per-block max-norm factor, as adaptive_scale_cols but with *floor*
    # rounding: the scaled max must land at or below the grid edge
    # (nearest-rounding could overshoot by sqrt(2) and clip the largest
    # values in the block by up to ~30%).
    m = jnp.max(jnp.abs(flat), axis=1)
    m = jnp.maximum(m, jnp.finfo(jnp.float32).tiny)
    sexp = jnp.clip(
        jnp.floor(jnp.log2(_quant_target(dt) / m)), -100, 100
    ).astype(jnp.int32)
    scale = jnp.ldexp(jnp.ones_like(m), sexp)
    q = flat * scale[:, None]
    if dt.kind == "i":
        q = jnp.clip(jnp.round(q), -127, 127)
    q = q.astype(dt).reshape(vals.shape)
    return q, (-sexp).reshape(lead)


def dequantize_block_vals(q, exp, dtype=jnp.float32):
    """Widen per-block quantized values: ``q * 2.0**exp`` in f32."""
    scale = jnp.ldexp(
        jnp.ones(exp.shape, jnp.float32), jnp.asarray(exp, jnp.int32)
    )
    return (
        q.astype(jnp.float32) * scale[..., None, None]
    ).astype(dtype)
