"""Pseudo-Hilbert ordering for arbitrary W x H tile grids.

The paper (Sec. III-A1) orders tomogram and sinogram tiles with a
*pseudo*-Hilbert curve so that contiguous ranges of the ordering form
spatially-compact subdomains.  We generate the classic Hilbert curve on
the enclosing power-of-two square (vectorized d->(x,y) bit manipulation)
and filter to in-bounds cells -- the standard pseudo-Hilbert construction
for non-square domains.  Filtering can skip cells (the curve is not
strictly step-contiguous at the padded boundary) but preserves the
property the decomposition actually relies on: *locality* -- any
contiguous chunk of the ordering has a compact bounding box
(tests/test_hilbert.py asserts this quantitatively).

The ordering is used at two levels (paper Fig. 4):
  * device level  -- contiguous chunks of the curve = per-device subdomains,
  * kernel level  -- contiguous runs inside a chunk = row-blocks handled by
    one Pallas grid step (the thread-block analogue).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "hilbert_curve_square",
    "gilbert2d",
    "hilbert_order",
    "hilbert_argsort",
    "tile_hilbert_order",
]


def _hilbert_d2xy(order: int, d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized distance -> (x, y) on a 2^order square Hilbert curve."""
    x = np.zeros_like(d)
    y = np.zeros_like(d)
    t = d.copy()
    s = 1
    while s < (1 << order):
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        # rotate quadrant
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f, y_f = x.copy(), y.copy()
        x = np.where(swap, y_f, x)
        y = np.where(swap, x_f, y)
        x = np.where(flip, s - 1 - x, x)
        y = np.where(flip, s - 1 - y, y)
        x = x + s * rx
        y = y + s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert_curve_square(order: int) -> np.ndarray:
    """Full curve on the 2^order square: [(x, y)] in curve order."""
    n = 1 << order
    d = np.arange(n * n, dtype=np.int64)
    x, y = _hilbert_d2xy(order, d)
    return np.stack([x, y], axis=1)


def gilbert2d(width: int, height: int) -> np.ndarray:
    """Pseudo-Hilbert curve over a W x H rectangle: ``(W*H, 2)`` (x, y).

    Power-of-two Hilbert on the enclosing square, filtered to in-bounds
    cells (name kept for API compatibility with the generalized-curve
    variant it replaces).
    """
    if width <= 0 or height <= 0:
        return np.zeros((0, 2), np.int64)
    side = max(width, height)
    order = max(1, int(np.ceil(np.log2(side)))) if side > 1 else 1
    pts = hilbert_curve_square(order)
    mask = (pts[:, 0] < width) & (pts[:, 1] < height)
    out = pts[mask]
    assert out.shape == (width * height, 2), (out.shape, width, height)
    return out


def hilbert_order(width: int, height: int) -> np.ndarray:
    """``order[k] = flat_index(x_k, y_k)``: curve position -> row-major cell.

    ``flat_index = y * width + x`` (row-major over the W x H grid).
    """
    pts = gilbert2d(width, height)
    return pts[:, 1] * width + pts[:, 0]


def hilbert_argsort(width: int, height: int) -> np.ndarray:
    """``rank[flat_index] = position along the curve`` (inverse of order)."""
    order = hilbert_order(width, height)
    rank = np.empty_like(order)
    rank[order] = np.arange(order.size)
    return rank


def tile_hilbert_order(
    n_rows: int, n_cols: int, tile: int
) -> tuple[np.ndarray, tuple[int, int]]:
    """Hilbert-order the cells of an ``n_rows x n_cols`` grid tile-wise.

    The grid is cut into ``tile x tile`` patches (paper Fig. 4a); patches
    are visited in pseudo-Hilbert order and cells inside a patch are
    visited row-major.  Returns ``(perm, (ty, tx))`` where ``perm`` maps
    curve position -> flat row-major cell index (exactly
    ``n_rows * n_cols`` entries) and ``(ty, tx)`` is the tile-grid shape.
    """
    ty = -(-n_rows // tile)
    tx = -(-n_cols // tile)
    patch_order = gilbert2d(tx, ty)  # (x = col-tile, y = row-tile)
    perm = np.empty(n_rows * n_cols, dtype=np.int64)
    k = 0
    for px, py in patch_order:
        r0, c0 = py * tile, px * tile
        rr = np.arange(r0, min(r0 + tile, n_rows))
        cc = np.arange(c0, min(c0 + tile, n_cols))
        if rr.size == 0 or cc.size == 0:
            continue
        block = (rr[:, None] * n_cols + cc[None, :]).ravel()
        perm[k : k + block.size] = block
        k += block.size
    assert k == n_rows * n_cols
    return perm, (ty, tx)
