"""3D partitioning: batch parallelism x Hilbert-ordered data parallelism.

Implements the paper's Sec. III-A for TPU meshes:

  * slices along the rotation axis are *batch*-parallel (no communication;
    they share the system matrix ``A``) -> mapped to the slow mesh axes;
  * each slice is *data*-parallel: tomogram voxels and sinogram rays are
    Hilbert-ordered (``core.hilbert``) and cut into ``P_d`` equal contiguous
    chunks -> mapped to the fast mesh axes;
  * each device's sparse shard is compiled into a static **blocked-ELL**
    layout consumed by the Pallas SpMM kernel: rows are grouped into
    row-blocks of ``R`` rows; every row-block is processed in ``S`` stages;
    a stage consumes ``K`` nnz slots per row and stages a *window* of at
    most ``BUF`` unique input columns into VMEM (the paper's multi-stage
    3D input buffering, Sec. III-B4, with the window playing the role of
    the 96 KB shared-memory buffer).

Per-nnz storage is 4 bytes -- int16 window index + fp16 length -- matching
the paper's ``{unsigned short ind; half len;}`` packing (Sec. III-C2).

The partial outputs of a device cover only a contiguous *band* of the
(Hilbert-ordered) output rows; band metadata drives the sparse-aware
banded exchange in ``dist.collectives`` (paper Fig. 6-7: the overlap of
partial-data footprints is what hierarchical communication exploits).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math

import numpy as np
import scipy.sparse as sp

from .geometry import XCTGeometry, build_system_matrix
from .hilbert import tile_hilbert_order

__all__ = [
    "PartitionConfig",
    "OperatorShards",
    "Plan",
    "build_plan",
    "build_sparse_exchange",
    "build_hier_sparse_exchange",
    "default_socket",
    "estimate_hier_sparse",
    "exchange_volume_params",
    "plan_key",
    "socket_chunk_layout",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Static knobs of the decomposition + kernel layout."""

    n_data: int = 1  # P_d: in-slice data-parallel devices
    tile: int = 8  # Hilbert patch side (cells)
    rows_per_block: int = 32  # R: kernel row-block height
    nnz_per_stage: int = 32  # K: nnz slots per row per stage
    index_dtype: type = np.int16  # window index (2 bytes, paper packing)
    value_dtype: type = np.float16  # stored lengths (2 bytes, paper packing)
    # Hilbert-aware socket assignment: with ``socket=G > 1``, device slot
    # ``p = f * n_slow + t`` (fast-axis-major, the runtime linearization)
    # owns Hilbert chunk ``t * G + f`` instead of chunk ``p`` -- every
    # socket holds G *consecutive* Hilbert chunks, so its members' band
    # footprints overlap and ``build_hier_sparse_exchange``'s merged-band
    # dedup actually bites.  Must equal the topology's fast-level size
    # (or 1 for the legacy identity layout).
    socket: int = 1
    # Window slot assignment (docs/architecture.md "Slot reordering"):
    #   "runs"        (default) stage membership by run-extension over the
    #                 row-block's sorted column union -- each stage's
    #                 window is a *contiguous* chunk of the union, so
    #                 winmap entries form long consecutive-source runs and
    #                 the coalesced DMA path issues few large copies;
    #   "first_seen"  the legacy CSR-position layout (stage = slot index
    #                 // K), kept as the A/B baseline: stage windows
    #                 sample strided chunks of every row, fragmenting the
    #                 union (92% length-1 segments at bench scale).
    slot_order: str = "runs"


@dataclasses.dataclass
class OperatorShards:
    """Blocked-ELL shards for one operator (A or A^T), stacked over devices.

    Rows are packed as *virtual rows*: a matrix row with more nnz than
    ``S * K`` slots is split across several virtual rows (its partials are
    summed by the output scatter-add), and virtual rows are packed densely
    into blocks of ``R``.  This keeps ELL padding at the ceil-rounding
    level (~1.2x nnz) instead of max-row-driven (measured 5-7x), and
    avoids empty rows entirely even though the footprint of a subdomain is
    a scattered O(1/sqrt(P_d)) subset of the (Hilbert-ordered) output rows
    (EXPERIMENTS.md §Perf XCT iteration: "row splitting").

    Shapes (P = n_data, B = virtual-row blocks, S = stages, R = rows/block,
    K = nnz slots/row/stage, BUF = window entries/stage):

      inds       [P, B, S, R, K]  window-local column index (int16)
      vals       [P, B, S, R, K]  intersection lengths (float32 master copy;
                                  cast to the precision policy's storage
                                  dtype at apply time)
      winmap     [P, B, S, BUF]   device-local input column ids to stage
                                  (int32: BUF-padded, scalar-prefetched to
                                  SMEM by the fused kernel, which DMAs the
                                  named rows HBM -> VMEM itself -- no
                                  staged window tensor exists in HBM)
      winsegs    [P, B, S, NSEG, 3]  run-length DMA segments
                                  ``{src_start, dst_start, len}`` from
                                  ``kernels.ops.winmap_segments``, sorted
                                  by descending copy length (``kernels.
                                  ops.sort_segments_by_class``): the
                                  slot reordering keeps source runs
                                  long, so the fused kernel's default
                                  coalesced path issues one strided copy
                                  per segment instead of one per row
      segoff     [P, B, S, NCLS+1]  per-length-class segment offsets into
                                  the sorted ``winsegs`` table: the
                                  kernel loops each power-of-two class
                                  over exactly its own slots (dynamic
                                  ``fori_loop`` bounds), so window DMA
                                  issue work is O(real segments), not
                                  O(classes x capacity)
      row_map    [P, B, R]        global (padded) output row of each
                                  virtual row; padding points at
                                  ``n_rows_pad`` (dropped by the scatter);
                                  duplicates (split rows) are summed
      foot_rows  list[P] of int64 arrays -- global rows with nnz per device
                                  (host-side only; drives exchange tables
                                  and the Table-IV volume accounting)
    """

    inds: np.ndarray
    vals: np.ndarray
    winmap: np.ndarray
    row_map: np.ndarray
    foot_rows: list
    n_rows_pad: int  # padded global output rows (multiple of P * chunk)
    n_cols_pad: int  # padded global input cols (multiple of P * chunk)
    rows_per_dev: int  # output ownership chunk
    cols_per_dev: int  # input ownership chunk
    nnz: int  # true nnz across devices (before padding)
    winsegs: np.ndarray | None = None  # [P, B, S, NSEG, 3] DMA segments
    segoff: np.ndarray | None = None  # [P, B, S, NCLS+1] class offsets

    @property
    def flat_rows(self) -> int:
        """Rows in the concatenated occupied-block space (B * R)."""
        return self.inds.shape[1] * self.inds.shape[3]

    @property
    def padded_nnz(self) -> int:
        return int(np.prod(self.inds.shape))

    def hbm_bytes(
        self, value_bytes: int | None = 2, index_bytes: int = 2
    ) -> int:
        """Resident HBM footprint of the operator (paper packed layout).

        Counts only what actually lives in HBM under in-kernel staging:
        the packed nnz slots plus the int32 ``winmap``/``row_map``
        metadata.  The staged ``[B, S, BUF, F]`` window tensor of the
        legacy gather path is a *transient*, not part of the operator --
        and the fused kernel never allocates it at all (its staging is
        the O(VMEM) double buffer, see ``kernels.xct_spmm.vmem_bytes``).

        ``value_bytes=None`` reads the width off ``vals`` itself (the
        shards normally hold the f32 master copy, so pass the policy's
        ``vals_bytes`` to price the packed form; ``None`` is for shards
        already stored narrow).  A 1-byte width adds the per-(block,
        stage) int32 dequantization-scale table the quantized tier
        carries alongside the values.
        """
        vb = (
            self.vals.dtype.itemsize if value_bytes is None else value_bytes
        )
        # quantized tier: one int32 exponent per (device, block, stage)
        scale_table = (
            int(np.prod(self.inds.shape[:3])) * 4 if vb == 1 else 0
        )
        segs = 0 if self.winsegs is None else self.winsegs.size
        offs = 0 if self.segoff is None else self.segoff.size
        return self.padded_nnz * (vb + index_bytes) + (
            self.winmap.size * 4
            + self.row_map.size * 4
            + segs * 4
            + offs * 4
            + scale_table
        )


@dataclasses.dataclass
class Plan:
    """Full per-volume partition plan (both operators + orderings).

    ``row_pos`` / ``col_pos`` map a padded *Hilbert* index to its
    *stored* (device-major) index when the socket-aware chunk layout is
    active (``cfg.socket > 1``): stored block ``p`` holds Hilbert chunk
    ``socket_chunk_layout(P, socket)[p]``.  ``None`` means identity
    (chunk ``p`` on device slot ``p``).
    """

    geo: XCTGeometry
    cfg: PartitionConfig
    row_perm: np.ndarray  # curve position -> flat sinogram cell
    col_perm: np.ndarray  # curve position -> flat voxel
    proj: OperatorShards  # rows = sinogram, cols = tomogram
    back: OperatorShards  # rows = tomogram, cols = sinogram
    row_pos: np.ndarray | None = None  # Hilbert idx -> stored idx (sino)
    col_pos: np.ndarray | None = None  # Hilbert idx -> stored idx (tomo)

    @property
    def n_data(self) -> int:
        return self.cfg.n_data


def _pad_to(x: int, m: int) -> int:
    return m * int(math.ceil(x / m))


def socket_chunk_layout(p_data: int, socket: int) -> np.ndarray:
    """``sigma[p]`` = Hilbert chunk owned by device slot ``p``.

    The runtime linearizes device slots fast-axis-major
    (``p = f * n_slow + t``, as ``jax.lax.axis_index(data_axes)`` does
    with the fast axis first), so under the identity layout socket ``t``
    owns chunks ``{t, n_slow + t, ...}`` -- *scattered* along the
    Hilbert curve, leaving the hier-sparse socket dedup little overlap
    (ROADMAP: "consecutive chunks currently land in different sockets").
    With ``sigma[f * n_slow + t] = t * G + f`` every socket owns ``G``
    consecutive chunks: adjacent subdomains whose band footprints shadow
    each other (paper Fig. 6-7).
    """
    if socket <= 1:
        return np.arange(p_data)
    if p_data % socket:
        raise ValueError(
            f"socket {socket} does not divide P_d={p_data}"
        )
    n_slow = p_data // socket
    p = np.arange(p_data)
    return (p % n_slow) * socket + p // n_slow


def _block_positions(sigma: np.ndarray, chunk: int) -> np.ndarray:
    """Padded Hilbert index -> stored index under chunk layout ``sigma``
    (stored block ``p`` holds Hilbert chunk ``sigma[p]``)."""
    inv = np.empty_like(sigma)
    inv[sigma] = np.arange(sigma.size)
    i = np.arange(sigma.size * chunk)
    return inv[i // chunk] * chunk + i % chunk


SLOT_ORDERS = ("runs", "first_seen")


def _runs_stage_assignment(
    cols: np.ndarray,
    blk: np.ndarray,
    vrow: np.ndarray,
    j_in_vrow: np.ndarray,
    n_virt: int,
    S: int,
    K: int,
    n_cols_pad: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Run-extension slot assignment for one device's nnz entries.

    Instead of the legacy CSR-position split (stage ``s`` takes slots
    ``[s*K, (s+1)*K)`` of every row, so each stage's window samples a
    *strided* subset of the row-block's columns), partition each
    row-block's sorted column union U into ``S`` equal contiguous chunks
    and let stage ``s`` own chunk ``s``.  Every stage window is then a
    contiguous slice of U, so consecutive winmap entries extend into
    long runs -- the coalesced DMA path's whole win
    (docs/architecture.md "Slot reordering").

    Per-row feasibility (a row may have more than ``K`` columns inside
    one chunk) is restored by a staircase repair on each virtual row's
    cumulative stage counts ``t[0..S]``: clamp forward
    ``t[s] <= t[s-1] + K`` then backward ``t[s] >= t[s+1] - K`` -- both
    passes keep ``t`` monotone with gaps <= K, and total nnz <= S*K per
    virtual row guarantees a feasible staircase.  Stage membership stays
    monotone along each row's sorted column order, so windows remain
    sorted and ELL slots fill densely from 0 within each stage.
    """
    if S == 1:
        return np.zeros_like(j_in_vrow), j_in_vrow
    # sorted unique columns per row-block (U), via one global unique
    bkey = blk * np.int64(n_cols_pad) + cols
    ub = np.unique(bkey)
    ub_blk = ub // n_cols_pad
    ub_col = ub % n_cols_pad
    n_blk = int(blk.max()) + 1
    cnt_b = np.bincount(ub_blk, minlength=n_blk)
    start_b = np.concatenate(([0], np.cumsum(cnt_b)[:-1]))
    # chunk boundaries: beta[b, s-1] = first column of block b's chunk s
    bidx = start_b[:, None] + (
        np.arange(1, S, dtype=np.int64) * cnt_b[:, None]
    ) // S
    beta = ub_col[bidx]  # [n_blk, S-1]
    nat = (cols[:, None] >= beta[blk]).sum(axis=1)  # natural stage
    # per-virtual-row staircase repair on cumulative counts
    counts = np.bincount(
        vrow * np.int64(S) + nat, minlength=n_virt * S
    ).reshape(n_virt, S)
    t = np.zeros((n_virt, S + 1), np.int64)
    np.cumsum(counts, axis=1, out=t[:, 1:])
    for s in range(1, S):
        np.minimum(t[:, s], t[:, s - 1] + K, out=t[:, s])
    for s in range(S - 1, 0, -1):
        np.maximum(t[:, s], t[:, s + 1] - K, out=t[:, s])
    stage = (j_in_vrow[:, None] >= t[vrow, 1:S]).sum(axis=1)
    slot = j_in_vrow - t[vrow, stage]
    return stage, slot


def _build_operator(
    a_perm: sp.csr_matrix,
    cfg: PartitionConfig,
    rows_per_dev: int,
    cols_per_dev: int,
) -> OperatorShards:
    """Compile a (row+col Hilbert-permuted) sparse matrix into blocked-ELL.

    Fully vectorized: per device, every nnz entry is assigned a destination
    (block, stage, row-in-block, slot) and a window-local column index in
    O(nnz log nnz) NumPy, no per-row Python loops.

    ``rows_per_dev`` / ``cols_per_dev`` are dictated by the plan so that the
    tomogram (x) and sinogram (y) vector spaces are *shared* between A and
    A^T -- CG hands one operator's output chunk straight to the other.
    """
    if cfg.slot_order not in SLOT_ORDERS:
        raise ValueError(
            f"unknown slot_order {cfg.slot_order!r}; one of {SLOT_ORDERS}"
        )
    P = cfg.n_data
    R, K = cfg.rows_per_block, cfg.nnz_per_stage
    n_rows, n_cols = a_perm.shape
    n_cols_pad = cols_per_dev * P
    n_rows_pad = rows_per_dev * P
    assert n_cols_pad >= n_cols and n_rows_pad >= n_rows

    a_csc = a_perm.tocsc()

    # --- pass 1: per-device virtual-row assignment; global B and S --------
    # S covers the mean row load (x1.35 headroom); rows needing more than
    # S*K slots are split into several virtual rows (partials summed by
    # the output scatter-add); virtual rows pack densely into R-blocks.
    per_dev: list[sp.csr_matrix] = []
    foot_rows: list[np.ndarray] = []  # per device: rows with nnz
    max_blocks = 1
    s_global = 1
    for p in range(P):
        c0, c1 = p * cols_per_dev, min((p + 1) * cols_per_dev, n_cols)
        sub = a_csc[:, c0:c1].tocsr()
        sub.sort_indices()
        per_dev.append(sub)
        nz_rows = np.flatnonzero(np.diff(sub.indptr))
        foot_rows.append(nz_rows.astype(np.int64))
        if nz_rows.size == 0:
            continue
        row_nnz = np.diff(sub.indptr)
        mean_nnz = row_nnz[nz_rows].mean()
        s_global = max(
            s_global, int(math.ceil(1.35 * mean_nnz / K))
        )
    S = s_global
    cap = S * K  # slots per virtual row

    staged = []
    for p in range(P):
        sub = per_dev[p]
        row_nnz = np.diff(sub.indptr)
        n_virt = int(np.ceil(row_nnz / cap).sum())
        max_blocks = max(max_blocks, int(math.ceil(n_virt / R)))
        staged.append(None)
    B = _pad_to(max(1, max_blocks), 8)

    # --- pass 2: per-device entry destinations + window construction ------
    # For each nnz: (block, stage, virtual-row-in-block, slot) destination,
    # plus the window-local column index obtained by grouping (block,
    # stage) and deduplicating columns inside each group.
    buf = 8
    nnz = 0
    for p in range(P):
        sub = per_dev[p]
        indptr, cols, data = sub.indptr, sub.indices, sub.data
        m = data.size
        nnz += int(m)
        if m == 0:
            continue
        row_of = np.repeat(
            np.arange(n_rows, dtype=np.int64), np.diff(indptr)
        )
        pos = np.arange(m, dtype=np.int64) - indptr[row_of]
        virt = pos // cap  # split index within the row
        # dense virtual-row ids: rank of (row, virt) among unique pairs
        vkey = row_of * np.int64(n_rows + 1) + virt
        uv, vrow = np.unique(vkey, return_inverse=True)
        blk = vrow // R
        ri = vrow % R
        j_in_vrow = pos % cap  # nnz rank within its virtual row
        if cfg.slot_order == "first_seen":
            # legacy CSR-position layout: stage windows sample strided
            # position chunks of every row (A/B baseline, fragmented)
            stage = j_in_vrow // K
            slot = j_in_vrow % K
        else:
            stage, slot = _runs_stage_assignment(
                cols, blk, vrow, j_in_vrow, uv.size, S, K, n_cols_pad
            )
        group = blk * S + stage  # [0, B*S)
        key = group * np.int64(n_cols_pad) + cols
        uk, inv = np.unique(key, return_inverse=True)
        ug = uk // n_cols_pad
        uc = uk % n_cols_pad
        gstart = np.searchsorted(ug, np.arange(B * S, dtype=np.int64))
        local = np.arange(uk.size, dtype=np.int64) - gstart[ug]
        buf = max(buf, int((local + 1).max()))
        staged[p] = (group, ri, slot, data, inv, ug, uc, local, uv)
    buf = _pad_to(buf, 8)
    assert buf < 32768, f"window {buf} overflows int16 index"

    # --- pass 3: materialize ---------------------------------------------
    inds = np.zeros((P, B, S, R, K), dtype=cfg.index_dtype)
    vals = np.zeros((P, B, S, R, K), dtype=np.float32)
    if cfg.slot_order == "first_seen":
        # legacy pad encoding: unused window slots read row 0, each its
        # own length-1 copy (kept bit-for-bit as the A/B baseline)
        winmap = np.zeros((P, B, S, buf), dtype=np.int32)
    else:
        # pad-slot encoding: initialize every window to arange so the
        # unused tail of a stage window (slots sz..buf-1) reads rows
        # sz..buf-1 -- one consecutive-source run (O(log buf) DMA
        # pieces) instead of buf-sz length-1 copies of row 0.  Safe:
        # buf <= cols_per_dev (asserted), so every pad source row
        # exists in the local slab.
        assert buf <= cols_per_dev, (buf, cols_per_dev)
        winmap = np.broadcast_to(
            np.arange(buf, dtype=np.int32), (P, B, S, buf)
        ).copy()
    row_map = np.full((P, B, R), n_rows_pad, dtype=np.int32)
    for p in range(P):
        if staged[p] is None:
            continue
        group, ri, slot, data, inv, ug, uc, local, uv = staged[p]
        flat_iv = inds[p].reshape(B * S, R, K)
        flat_vv = vals[p].reshape(B * S, R, K)
        flat_iv[group, ri, slot] = local[inv].astype(cfg.index_dtype)
        flat_vv[group, ri, slot] = data
        winmap[p].reshape(B * S, buf)[ug, local] = uc
        vrows = (uv // np.int64(n_rows + 1)).astype(np.int32)
        row_map[p].reshape(-1)[: vrows.size] = vrows

    from ..kernels.ops import sort_segments_by_class, winmap_segments

    # run-length coalesced DMA plan for the fused kernel's default path:
    # one strided copy per segment, the table sorted by length class so
    # the kernel loops each class over exactly its own slots
    winsegs, segoff = sort_segments_by_class(winmap_segments(winmap), buf)
    return OperatorShards(
        inds=inds,
        vals=vals,
        winmap=winmap,
        row_map=row_map,
        foot_rows=foot_rows,
        n_rows_pad=n_rows_pad,
        n_cols_pad=n_cols_pad,
        rows_per_dev=rows_per_dev,
        cols_per_dev=cols_per_dev,
        nnz=nnz,
        winsegs=winsegs,
        segoff=segoff,
    )


def build_plan(
    geo: XCTGeometry,
    cfg: PartitionConfig,
    a: sp.csr_matrix | None = None,
) -> Plan:
    """Build the full partition plan for one scan geometry.

    ``a`` may be passed in to reuse a prebuilt system matrix (memoization
    across precision policies in benchmarks).
    """
    if a is None:
        a = build_system_matrix(geo)
    # Hilbert orderings for both domains (paper Fig. 4a: square patches).
    col_perm, _ = tile_hilbert_order(geo.n, geo.n, cfg.tile)
    row_perm, _ = tile_hilbert_order(geo.n_angles, geo.num_det, cfg.tile)
    a_perm = a[row_perm][:, col_perm].tocsr()
    # Shared vector-space chunking: tomogram chunk serves as proj input and
    # back output; sinogram chunk as proj output and back input.
    P, R = cfg.n_data, cfg.rows_per_block
    align = max(8, R)
    tomo_chunk = _pad_to(int(math.ceil(geo.n_vox / P)), align)
    sino_chunk = _pad_to(int(math.ceil(geo.n_rays / P)), align)
    # Socket-aware chunk layout: relabel both vector spaces device-major
    # (stored block p = Hilbert chunk sigma[p]) so every downstream
    # consumer -- exchange tables, dense reduce-scatter ownership, the
    # shards themselves -- keeps its identity owner = index // chunk
    # arithmetic while sockets end up holding consecutive Hilbert chunks.
    sigma = socket_chunk_layout(P, cfg.socket)
    if cfg.socket > 1:
        row_pos = _block_positions(sigma, sino_chunk)
        col_pos = _block_positions(sigma, tomo_chunk)
        coo = a_perm.tocoo()
        a_dev = sp.csr_matrix(
            (coo.data, (row_pos[coo.row], col_pos[coo.col])),
            shape=(sino_chunk * P, tomo_chunk * P),
        )
    else:
        row_pos = col_pos = None
        a_dev = a_perm
    proj = _build_operator(a_dev, cfg, sino_chunk, tomo_chunk)
    back = _build_operator(a_dev.T.tocsr(), cfg, tomo_chunk, sino_chunk)
    return Plan(
        geo=geo, cfg=cfg, row_perm=row_perm, col_perm=col_perm,
        proj=proj, back=back, row_pos=row_pos, col_pos=col_pos,
    )


def estimate_plan(geo: XCTGeometry, cfg: PartitionConfig) -> Plan:
    """Analytic shard-shape estimation for dry-run lowering at full scale.

    Returns a Plan whose OperatorShards carry ``jax.ShapeDtypeStruct``
    leaves (no allocation, no system-matrix build -- Brain-scale nnz is
    ~7e11).  Geometry model (constants calibrated against real plans at
    n in [64, 256], see tests/test_partition.py::test_estimate_matches):

      * footprint rows/device ~ 1.8 * n_rows / sqrt(P)   (sqrt2 shadow x
        ~1.27 Hilbert-scatter/imbalance margin)
      * max per-device row nnz ~ min(1.45 n, 2.4 n / sqrt(P))  (proj);
        for A^T rows are voxels: ~ min(1.3 K, 2.4 * 1.3 K / sqrt(P))
      * window BUF ~ 6 (R + K), pair volume V ~ 2.5 * foot / P
    """
    import jax as _jax

    P, R, K = cfg.n_data, cfg.rows_per_block, cfg.nnz_per_stage
    align = max(8, R)
    tomo_chunk = _pad_to(int(math.ceil(geo.n_vox / P)), align)
    sino_chunk = _pad_to(int(math.ceil(geo.n_rays / P)), align)
    nnz_total = geo.n_rays * 1.195 * geo.n
    sqrt_p = math.sqrt(P)

    def one(n_rows, n_cols, rows_per_dev, cols_per_dev):
        from ..kernels.traffic import est_segments_per_stage
        from ..kernels.xct_spmm import _dma_classes

        foot = min(n_rows, int(1.8 * n_rows / sqrt_p) + R)
        mean_nnz = nnz_total / P / max(foot, 1)
        s = max(1, int(math.ceil(1.35 * mean_nnz / K)))
        # virtual rows: one per footprint row plus splits for fat rows,
        # ~1.2x slot utilization headroom
        vrows = int(1.2 * max(foot, nnz_total / P / (s * K)))
        b = _pad_to(max(1, int(math.ceil(vrows / R))), 8)
        buf = _pad_to(min(6 * (R + K), R * K), 8)
        nseg = _pad_to(
            est_segments_per_stage(buf, slot_order=cfg.slot_order), 8
        )
        v = _pad_to(max(8, int(2.5 * vrows / P)), 8)
        sds = _jax.ShapeDtypeStruct
        op = OperatorShards(
            inds=sds((P, b, s, R, K), np.int16),
            vals=sds((P, b, s, R, K), np.float32),
            winmap=sds((P, b, s, buf), np.int32),
            winsegs=sds((P, b, s, nseg, 3), np.int32),
            segoff=sds((P, b, s, len(_dma_classes(buf)) + 1), np.int32),
            row_map=sds((P, b, R), np.int32),
            foot_rows=None,
            n_rows_pad=rows_per_dev * P,
            n_cols_pad=cols_per_dev * P,
            rows_per_dev=rows_per_dev,
            cols_per_dev=cols_per_dev,
            nnz=int(nnz_total),
        )
        op.est_v = v  # type: ignore[attr-defined]
        op.est_foot = foot  # type: ignore[attr-defined]
        # chunk layout marker: lets estimate_hier_sparse pick the
        # adjacent-chunk union model for socket-aware plans
        op.est_socket = cfg.socket  # type: ignore[attr-defined]
        return op

    proj = one(geo.n_rays, geo.n_vox, sino_chunk, tomo_chunk)
    back = one(geo.n_vox, geo.n_rays, tomo_chunk, sino_chunk)
    return Plan(
        geo=geo, cfg=cfg, row_perm=None, col_perm=None,
        proj=proj, back=back,
    )


def build_sparse_exchange(
    op: OperatorShards,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Static index tables for the footprint-compressed exchange.

    For every (sender p, receiver q) pair, the virtual-row slots of p
    whose global row lands in q's owned chunk (split rows contribute one
    entry per virtual row; the receiver scatter-add sums them).  Padding:
    send indices point at the appended zero row (``flat_rows``), receive
    indices at the trash row (``rows_per_dev``) -- see
    ``dist.collectives.sparse_exchange``.

    Returns ``(send_idx [P,P,V], recv_idx [P,P,V], V)``.
    """
    P = op.inds.shape[0]
    rpd = op.rows_per_dev
    counts = np.zeros((P, P), dtype=np.int64)
    pair_rows: dict[tuple[int, int], tuple] = {}
    for p in range(P):
        rm = op.row_map[p].reshape(-1)  # [B*R] global row per vrow slot
        flat = np.flatnonzero(rm < op.n_rows_pad)
        if flat.size == 0:
            continue
        rows = rm[flat].astype(np.int64)
        owner = rows // rpd
        order = np.argsort(owner, kind="stable")
        rows_s, flat_s, owner_s = rows[order], flat[order], owner[order]
        uq, start = np.unique(owner_s, return_index=True)
        bounds = np.append(start, owner_s.size)
        for i, q in enumerate(uq):
            sel = slice(bounds[i], bounds[i + 1])
            pair_rows[(p, int(q))] = (rows_s[sel], flat_s[sel])
            counts[p, q] = bounds[i + 1] - bounds[i]
    v = _pad_to(max(1, int(counts.max())), 8)
    flat_rows = op.flat_rows
    send = np.full((P, P, v), flat_rows, dtype=np.int32)
    recv = np.full((P, P, v), rpd, dtype=np.int32)
    for (p, q), (rows, flat) in pair_rows.items():
        send[p, q, : rows.size] = flat
        recv[q, p, : rows.size] = rows - q * rpd
    return send, recv, v


def build_hier_sparse_exchange(
    op: OperatorShards, fast: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int, int]:
    """Static tables for the *hierarchical* footprint exchange
    (plan mode ``hier-sparse``).

    Devices are linearized fast-axis-major (``p = f * n_slow + t``, as in
    ``jax.lax.axis_index(data_axes)``): a *socket* ``t`` is the group of
    ``G = fast`` devices that share the fast link.  Socket members' band
    footprints overlap (paper Fig. 6-7: nearby Hilbert chunks shadow the
    same output rows), so instead of every member shipping its own copy
    across the slow links (flat ``sparse``), the socket first merges:

      stage 1   every member scatter-adds its band into the socket's
                *merged band* -- the union of member footprints, laid out
                grouped by the owner device's fast index ``f`` and padded
                to ``W`` rows per group -- and a reduce-scatter over the
                fast axis leaves member ``f`` holding group ``f``, fully
                summed within the socket (the dedup: overlapping rows
                cross the fast link once instead of the slow link
                ``G`` times);
      stage 2   member ``f``'s group contains exactly the rows owned by
                devices ``(f, t')``, so one sparse all-to-all over the
                *slow* axes delivers every row straight to its owner --
                no post-exchange intra-socket routing;
      stage 3   the owner scatter-adds received slots into its chunk.

    Returns ``(socket_map [P, flat_rows], send2 [P, n_slow, V2],
    recv2 [P, n_slow, V2], W, V2)``:

      socket_map  merged-band slot per local band slot (trash = G*W)
      send2       per slow peer, slots of my W-group to ship (pad = W)
      recv2       owned-chunk row per incoming slot (pad = rows_per_dev)
    """
    P = op.inds.shape[0]
    if P % fast:
        raise ValueError(f"fast size {fast} does not divide P={P}")
    G, n_slow = fast, P // fast
    rpd = op.rows_per_dev
    # per-device valid (band slot, global row) from the virtual-row map
    dev_slots, dev_rows = [], []
    for p in range(P):
        rm = op.row_map[p].reshape(-1)
        sl = np.flatnonzero(rm < op.n_rows_pad)
        dev_slots.append(sl)
        dev_rows.append(rm[sl].astype(np.int64))

    # merged band per socket: union of member rows, grouped by the owner's
    # fast index (monotone in row, so the union stays sorted per group)
    sockets = []  # per t: (uniq_rows, owner_fast, group_starts)
    w = 1
    for t in range(n_slow):
        allr = np.concatenate(
            [dev_rows[f * n_slow + t] for f in range(G)]
        )
        uniq = np.unique(allr)
        owner_f = (uniq // rpd) // n_slow
        counts = np.bincount(owner_f, minlength=G)
        w = max(w, int(counts.max()))
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        sockets.append((uniq, owner_f, starts))
    w = _pad_to(w, 8)

    flat_rows = op.flat_rows
    socket_map = np.full((P, flat_rows), G * w, dtype=np.int32)
    for p in range(P):
        t = p % n_slow
        uniq, owner_f, starts = sockets[t]
        if dev_rows[p].size == 0:
            continue
        i = np.searchsorted(uniq, dev_rows[p])
        socket_map[p, dev_slots[p]] = (
            owner_f[i] * w + (i - starts[owner_f[i]])
        ).astype(np.int32)

    # stage 2: per (socket t, fast f), the W-group rows split by the
    # owner's slow index; sender (f, t) block t' pairs with receiver
    # (f, t') block t
    v2 = 1
    group_rows: dict[tuple[int, int], list] = {}
    for t in range(n_slow):
        uniq, owner_f, starts = sockets[t]
        for f in range(G):
            rows = uniq[owner_f == f]  # W-group of member (f, t), sorted
            owner_t = (rows // rpd) % n_slow
            per_peer = [
                (np.flatnonzero(owner_t == t2), rows[owner_t == t2])
                for t2 in range(n_slow)
            ]
            group_rows[(f, t)] = per_peer
            if per_peer:
                v2 = max(v2, max(w_.size for w_, _ in per_peer))
    v2 = _pad_to(v2, 8)

    send2 = np.full((P, n_slow, v2), w, dtype=np.int32)
    recv2 = np.full((P, n_slow, v2), rpd, dtype=np.int32)
    for p in range(P):
        f, t = p // n_slow, p % n_slow
        for t2, (slots, rows) in enumerate(group_rows[(f, t)]):
            send2[p, t2, : slots.size] = slots
            q = f * n_slow + t2  # receiver of this block
            recv2[q, t, : rows.size] = rows - q * rpd
    return socket_map, send2, recv2, w, v2


def estimate_hier_sparse(
    op: OperatorShards,
    fast: int,
    n_slow: int,
    *,
    socket_aware: bool | None = None,
) -> tuple[int, int]:
    """Estimated ``(W, V2)`` for abstract plans (no tables built).

    Two union models, selected by the plan's chunk layout:

      * legacy scattered layout (``PartitionConfig(socket=1)``): socket
        members' footprints are independent draws of ``est_foot`` rows
        from the padded row space, so the merged band is
        ``R * (1 - (1 - foot/R)^G)`` rows;
      * socket-aware layout (``socket=G``; the default the dry-run sweep
        picked, see ``launch.dryrun.socket_sweep``): members own *G
        consecutive* Hilbert chunks, i.e. one contiguous subdomain
        covering ``1/n_slow`` of the curve, so the union follows the
        same sqrt shadow law as a single subdomain's footprint:
        ``min(R, 1.9 * R / sqrt(n_slow))``.  The constant is calibrated
        against measured ``build_hier_sparse_exchange`` tables at
        n in [32, 64] (est/real W in [0.9, 1.6]; pinned by
        ``tests/test_partition.py::test_estimate_hier_sparse_adjacent``)
        the same way ``estimate_plan``'s constants were.  At xct-brain
        scale the adjacent model is ~2.3x tighter than the
        independent-draw union (which the ROADMAP flagged as
        overstating W for socket-aware plans).

    ``socket_aware=None`` infers the layout from the operator's
    ``est_socket`` attribute (attached by :func:`estimate_plan` from
    ``cfg.socket``).  ``V2`` carries the usual ~1.6x imbalance margin
    over the even split of a W-group across slow peers.
    """
    rows = float(op.n_rows_pad)
    foot = float(getattr(op, "est_foot", 0.0)) or 1.8 * rows / math.sqrt(
        max(1, fast * n_slow)
    )
    if socket_aware is None:
        socket_aware = fast > 1 and getattr(op, "est_socket", 1) == fast
    if socket_aware:
        union = max(
            foot, min(rows, 1.9 * rows / math.sqrt(max(1, n_slow)))
        )
    else:
        union = rows * (1.0 - (1.0 - min(1.0, foot / rows)) ** fast)
    w = _pad_to(max(8, int(math.ceil(union / fast))), 8)
    v2 = _pad_to(max(8, int(1.6 * w / max(1, n_slow))), 8)
    return w, v2


def hier_sparse_wire_bytes(
    v2: int,
    n_slow: int,
    f: int,
    *,
    comm_bytes: int = 2,
    wire: str = "native",
) -> int:
    """Per-device DCI payload of one hier-sparse slow-axis all-to-all.

    ``native`` ships the partial sums in the policy's wire dtype:
    ``n_slow * V2 * F * comm_bytes``.  ``q8`` ships int8 values plus one
    f32 inverse scale per (slow peer, fused slice) -- the per-band
    compression ``dist.collectives.sparse_exchange(wire="q8")`` applies
    around the all-to-all:

    >>> hier_sparse_wire_bytes(1024, 4, 16, comm_bytes=2)
    131072
    >>> hier_sparse_wire_bytes(1024, 4, 16, comm_bytes=2, wire="q8")
    65792
    >>> _ / 131072  # doctest: +ELLIPSIS
    0.501953125
    """
    if wire == "native":
        return n_slow * v2 * f * comm_bytes
    if wire == "q8":
        return n_slow * v2 * f * 1 + n_slow * f * 4
    raise ValueError(f"unknown wire {wire!r}; one of ('native', 'q8')")


def default_socket(p_data: int, fast: int) -> int:
    """The socket layout a driver should use for a ``fast``-wide ladder.

    The ROADMAP's dry-run sweep at xct-brain scale
    (``launch.dryrun.socket_sweep``: socket=1 vs socket=fast-size at
    P_d = 512) picked the socket-aware layout -- consecutive Hilbert
    chunks per socket shrink the hier-sparse merged band, strictly
    reducing modeled DCI.  So: ``fast`` whenever it legally divides the
    device count, else the legacy scattered layout.
    """
    return fast if fast > 1 and p_data % fast == 0 else 1


def _key_scalar(v):
    """Canonicalize one fingerprint value (see :func:`plan_key`)."""
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):
        # repr round-trips doubles exactly; 1.0 and 1 must not collide
        # with each other across runs, so floats keep a "f:" tag
        return f"f:{v!r}"
    if isinstance(v, type) or isinstance(v, np.dtype):
        return np.dtype(v).name  # np.int16 / "int16" / dtype -> one name
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {
            f.name: _key_scalar(getattr(v, f.name))
            for f in dataclasses.fields(v)
        }
    raise TypeError(
        f"plan_key cannot fingerprint {type(v).__name__}: {v!r} "
        "(pass scalars, dtypes, or dataclasses of those)"
    )


def plan_key(
    geo: XCTGeometry, cfg: PartitionConfig = PartitionConfig(), **runtime
) -> str:
    """Stable fingerprint of everything that shapes a compiled plan.

    Two jobs share a cold path -- partition + winseg build + kernel
    compile -- exactly when they agree on (a) the scan geometry, (b) the
    decomposition/block layout (``PartitionConfig``: P_d, tile, R, K,
    the index/value dtype packing, socket layout) and (c) whichever
    runtime knobs the caller folds in (``repro.serve`` passes the full
    ``ReconConfig``: precision ladder, comm mode, fuse, staging/DMA
    mode).  ``plan_key`` hashes all of it into one short stable string
    so a plan cache can amortize the cold path across jobs
    (docs/architecture.md, "Reconstruction-as-a-service").

    Properties the serve layer relies on (pinned in
    ``tests/test_partition.py``):

      * deterministic across processes (no ``hash()`` randomization --
        the digest is sha256 over a canonical JSON encoding);
      * kwargs order never matters (``precision=..., comm_mode=...`` ==
        ``comm_mode=..., precision=...``: keys are sorted);
      * near-miss configs do NOT collide: a different value dtype, a
        different socket, a different comm/dma mode each change the key;
      * equivalent geometries DO collide (``n_det=None`` vs an explicit
        ``n_det=n`` name the same scan, so they share a cache entry).

    ``runtime`` values may be scalars, dtypes, or dataclasses of those
    (e.g. ``recon=ReconConfig(...)``); anything else raises TypeError
    rather than fingerprinting an unstable repr.
    """
    record = {
        # geometry, canonicalized: num_det resolves the n_det=None alias
        "geo": {
            "n": geo.n,
            "n_angles": geo.n_angles,
            "num_det": geo.num_det,
            "vox": _key_scalar(float(geo.vox)),
        },
        "partition": _key_scalar(cfg),
        "runtime": {k: _key_scalar(v) for k, v in runtime.items()},
    }
    blob = json.dumps(record, sort_keys=True, separators=(",", ":"))
    return "xct-" + hashlib.sha256(blob.encode()).hexdigest()[:16]


def exchange_volume_params(op: OperatorShards, topo) -> dict:
    """Wire-volume parameters for ``Topology.plan(mode, **params)``.

    One call covers every mode (``direct``/``rs``/``hier`` ignore the
    extras): ``pair_slots`` (flat sparse V), ``merged_rows`` (hier-sparse
    G*W) and ``cross_rows`` (n_slow*V2) plus ``dense_rows``.  Exact table
    capacities when the operator carries real shards; the analytic
    estimates (``est_v`` / :func:`estimate_hier_sparse`) for abstract
    ``estimate_plan`` shards.
    """
    fast = topo.levels[0].size if topo.levels else 1
    n_slow = max(1, topo.n_data // fast)
    # building the exact tables is O(P^2 V); memoize per ladder shape so
    # sweeps interrogating many (mode, fuse) cells pay it once
    cache = getattr(op, "_volume_params", None)
    if cache is None:
        cache = {}
        op._volume_params = cache  # type: ignore[attr-defined]
    key = (fast, n_slow)
    if key not in cache:
        if isinstance(op.row_map, np.ndarray):
            _, _, v = build_sparse_exchange(op)
            _, _, _, w, v2 = build_hier_sparse_exchange(op, fast)
        else:
            v = int(getattr(op, "est_v", 8))
            w, v2 = estimate_hier_sparse(op, fast, n_slow)
        cache[key] = {
            "pair_slots": v,
            "dense_rows": op.n_rows_pad,
            "merged_rows": fast * w,
            "cross_rows": n_slow * v2,
        }
    return dict(cache[key])
