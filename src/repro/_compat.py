"""Runtime compatibility with the installed jax (0.4.x LTS line).

The codebase is written against the modern jax surface -- ``jax.shard_map``
with ``check_vma`` / partial-manual ``axis_names``, ``jax.sharding.AxisType``
and ``jax.make_mesh(..., axis_types=...)``.  The deployment image pins
jax 0.4.37, where the same functionality lives under
``jax.experimental.shard_map`` (``check_rep`` / ``auto``) and meshes carry
no axis types at all (every axis behaves like today's ``Auto``).

``install()`` bridges the gap *in the jax namespace* so that call sites --
including test scripts that build meshes directly -- run unmodified on
either version.  Each shim is installed only when the attribute is
missing, so on a modern jax this module is a no-op.

Imported for its side effect from ``repro/__init__.py``.
"""
from __future__ import annotations

import enum
import inspect

import jax

__all__ = ["install"]


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        return
    _orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, devices=None,
                  axis_types=None):
        # 0.4.x meshes have no axis-type concept; every axis is usable
        # both under jit (auto) and shard_map (manual), which is exactly
        # the ``Auto`` semantics the callers request.
        del axis_types
        return _orig(axis_shapes, axis_names, devices=devices)

    make_mesh.__wrapped__ = _orig
    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma=True, axis_names=None, **kwargs):
        """Modern-signature wrapper over ``jax.experimental.shard_map``.

        ``check_vma`` maps to the old ``check_rep``; ``axis_names`` (the
        set of *manual* axes) maps to its complement ``auto`` (the set of
        axes left to the compiler).
        """
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, auto=auto, **kwargs
        )

    jax.shard_map = shard_map


def _install_pallas_params() -> None:
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:  # pragma: no cover - pallas unavailable
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(
        pltpu, "TPUCompilerParams"
    ):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()
    _install_pallas_params()
