"""Per-key circuit breaker (closed -> open -> half-open -> closed).

Guards expensive, shared build paths -- the serve plan cache's cold
``build_plan`` + ``Reconstructor`` jit -- from being hammered by a
poison key: after ``threshold`` consecutive failures the key's circuit
*opens* and callers are turned away instantly (the server maps that to
a terminal ``rejected_circuit`` job status) until ``cooldown_s``
elapses, when one *half-open* probe is let through.  A probe success
closes the circuit; a probe failure re-opens it for another cooldown.

The clock is injectable so the state machine is testable (and
doc-testable) without sleeping:

>>> t = {"now": 0.0}
>>> cb = CircuitBreaker(threshold=2, cooldown_s=30.0,
...                     clock=lambda: t["now"])
>>> cb.allow("plan-a")
True
>>> cb.record_failure("plan-a"); cb.state("plan-a")
'closed'
>>> cb.record_failure("plan-a"); cb.state("plan-a")  # trips at 2
'open'
>>> cb.allow("plan-a")
False
>>> t["now"] = 31.0
>>> cb.state("plan-a"), cb.allow("plan-a")  # cooldown over: one probe
('half_open', True)
>>> cb.record_success("plan-a"); cb.state("plan-a")
'closed'
"""
from __future__ import annotations

import threading
import time
from typing import Callable

__all__ = ["CircuitBreaker"]


class CircuitBreaker:
    """Consecutive-failure breaker with cooldown, one circuit per key."""

    def __init__(
        self,
        threshold: int = 3,
        cooldown_s: float = 60.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._fails: dict = {}  # key -> consecutive failures
        self._open_until: dict = {}  # key -> cooldown deadline
        self._lock = threading.Lock()

    def _state(self, key, now: float) -> str:
        if key in self._open_until:
            return "open" if now < self._open_until[key] else "half_open"
        return "closed"

    def state(self, key) -> str:
        with self._lock:
            return self._state(key, self._clock())

    def allow(self, key) -> bool:
        """May a caller attempt this key right now?"""
        with self._lock:
            return self._state(key, self._clock()) != "open"

    def record_failure(self, key) -> None:
        with self._lock:
            n = self._fails.get(key, 0) + 1
            self._fails[key] = n
            # trips at threshold; a failed half-open probe (already past
            # it) re-opens for another cooldown
            if n >= self.threshold:
                self._open_until[key] = self._clock() + self.cooldown_s

    def record_success(self, key) -> None:
        with self._lock:
            self._fails.pop(key, None)
            self._open_until.pop(key, None)
