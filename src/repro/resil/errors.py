"""Typed failures of the resilience layer.

The retry/quarantine machinery dispatches on exception *type*, so every
failure mode the chaos harness can provoke (and every real one it
models) gets a named class here:

* :class:`CorruptShardError` -- a shard's bytes do not match the crc
  recorded in its store manifest.  An ``OSError`` subclass, so the
  generic transient-I/O retry classes cover it, but the retry loop
  special-cases it to *one* re-read (a deterministic disk corruption
  will not heal, a torn page-cache read might).
* :class:`NonFiniteSolveError` -- the CG solve returned NaN/Inf.  The
  streaming driver retries, then re-solves one precision rung up
  (q8/fp8 -> f32) before quarantining the slab.
* :class:`DeadlineExceeded` -- a serve job ran past its
  ``JobSpec.deadline_s``.
* ``Injected*`` -- raised only by :mod:`repro.resil.inject` when a
  :class:`~repro.resil.inject.FaultPlan` is active; each subclasses the
  real-world exception it stands in for, so recovery code never
  special-cases injection.
"""
from __future__ import annotations

__all__ = [
    "CorruptShardError",
    "NonFiniteSolveError",
    "DeadlineExceeded",
    "InjectedIOError",
    "InjectedThreadDeath",
    "InjectedError",
    "InjectedPreemption",
]


class CorruptShardError(OSError):
    """A store shard failed its manifest crc check."""


class NonFiniteSolveError(FloatingPointError):
    """A solve produced NaN/Inf values."""


class DeadlineExceeded(RuntimeError):
    """A serve job exceeded its ``JobSpec.deadline_s``."""


class InjectedIOError(OSError):
    """A ``kind="io_error"`` fault (stands in for a failed disk read)."""


class InjectedThreadDeath(RuntimeError):
    """A ``kind="thread_death"`` fault (kills the prefetch worker)."""


class InjectedError(RuntimeError):
    """A generic ``kind="error"`` fault (e.g. a plan build failing)."""


class InjectedPreemption(RuntimeError):
    """A ``kind="preempt"`` fault (the job was killed mid-drain)."""
