"""Deterministic fault injection: seeded plans fired at named sites.

At the paper's scale (24,576 GPUs, day-long campaigns) "some node is
always slow and something is always failing" -- so the recovery paths
must be *testable*, and testable means deterministic.  A
:class:`FaultPlan` is a pure function of ``(seed, site, key, attempt)``:
the same plan against the same drain injects the same faults at the
same points, every run, on every machine.

Sites are consulted by production code via two module functions:

* :func:`fire` -- raise/delay-style faults (``io_error``, ``slow``,
  ``thread_death``, ``error``, ``preempt``);
* :func:`mutate` -- data faults applied to an array in flight
  (``corrupt`` flips shard bytes, ``nonfinite`` poisons solve output)
  plus all of the above.

Both are **zero-overhead when no plan is active**: one module-attribute
load and a ``None`` check (the ``chaos-smoke`` CI bench guard pins that
the clean path's throughput is unchanged with these sites compiled in).

The wired sites:

=================== ======================= ============================
site                key                     kinds that make sense
=================== ======================= ============================
``store/read``      shard start slice       io_error, corrupt, slow
``stream/load``     slab index              io_error, slow, thread_death
``stream/stage``    slab index              io_error, slow
``recon/solve``     scope key (slab index)  nonfinite
``serve/build``     ``None``                error
``stream/after_slab`` slab index            preempt
=================== ======================= ============================

Attempt counting is automatic: each consultation of ``(site, key)``
under an active plan increments that pair's attempt counter, so
``attempts=(0,)`` means "fire the first time only" -- the transient
fault that heals on retry -- and ``attempts=None`` means "fire every
time" -- the poison that exhausts retries.  Keyless call sites (the
solver does not know which slab it is solving) resolve their key from
the innermost :func:`scope` on the current thread.

Every fired fault bumps ``faults_injected_total{site,kind}`` and drops
a ``resil/fault`` trace instant, so a chaos run's artifact shows
exactly what was injected where.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import threading
import time

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .errors import (
    InjectedError,
    InjectedIOError,
    InjectedPreemption,
    InjectedThreadDeath,
)

__all__ = ["Fault", "FaultPlan", "activate", "active", "fire", "mutate",
           "scope", "hash01"]

KINDS = (
    "io_error", "corrupt", "slow", "thread_death", "nonfinite",
    "error", "preempt",
)

_RAISES = {
    "io_error": InjectedIOError,
    "thread_death": InjectedThreadDeath,
    "error": InjectedError,
    "preempt": InjectedPreemption,
}


def hash01(seed: int, *parts) -> float:
    """Deterministic uniform in ``[0, 1)`` from ``(seed, *parts)``.

    The single entropy source of the whole resilience layer: fault
    byte positions and retry jitter both come from here, so a chaos
    scenario replays bit-identically from its seed.
    """
    msg = ":".join(repr(p) for p in (seed,) + parts).encode()
    u = int.from_bytes(hashlib.sha256(msg).digest()[:8], "big")
    return u / 2.0**64


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injection rule: where, what, and on which attempts.

    ``key=None`` matches any key at the site; ``attempts=None`` fires on
    every consultation (a persistent fault), ``attempts=(0,)`` only on
    the first (a transient one).  ``when`` is an optional attrs match
    against the call site's context (e.g. ``{"precision": "q8"}`` makes
    a ``nonfinite`` fault poison only the quantized rung, so the
    driver's precision escalation can be seen to succeed).
    """

    site: str
    kind: str
    key: object = None
    attempts: tuple | None = (0,)
    delay_s: float = 0.05  # kind="slow" stall length
    flip_bytes: int = 1  # kind="corrupt" bytes to flip
    when: tuple | None = None  # (("attr", value), ...) context match

    def fires(self, key, attempt: int, ctx: dict | None) -> bool:
        if self.key is not None and self.key != key:
            return False
        if self.attempts is not None and attempt not in self.attempts:
            return False
        if self.when is not None:
            ctx = ctx or {}
            if any(ctx.get(k) != v for k, v in self.when):
                return False
        return True


class FaultPlan:
    """A seeded set of :class:`Fault` rules (chain ``.add`` to build)."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._faults: list[Fault] = []

    def add(self, site: str, kind: str, *, key=None, attempts=(0,),
            delay_s: float = 0.05, flip_bytes: int = 1,
            when: dict | None = None) -> "FaultPlan":
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; one of {KINDS}")
        self._faults.append(Fault(
            site=site, kind=kind, key=key,
            attempts=None if attempts is None else tuple(attempts),
            delay_s=float(delay_s), flip_bytes=int(flip_bytes),
            when=None if when is None else tuple(sorted(when.items())),
        ))
        return self

    def faults_at(self, site: str) -> list[Fault]:
        return [f for f in self._faults if f.site == site]

    def __len__(self) -> int:
        return len(self._faults)


class _Active:
    """A plan bound to the registry: per-``(site, key)`` attempt
    counters plus the log of every fault actually fired."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: list[tuple] = []  # (site, key, attempt, kind)
        self._counts: dict = {}
        self._lock = threading.Lock()

    def next_attempt(self, site: str, key) -> int:
        with self._lock:
            n = self._counts.get((site, key), 0)
            self._counts[(site, key)] = n + 1
            return n


# The fast path: one attribute load + None check when nothing is active.
_active_plan: _Active | None = None
_scope = threading.local()


@contextlib.contextmanager
def activate(plan: FaultPlan):
    """Bind ``plan`` to the registry for the duration of the block.

    Attempt counters start fresh per activation (re-running the same
    scenario re-fires the same faults).  Yields the :class:`_Active`
    handle so tests can assert on ``handle.fired``.
    """
    global _active_plan
    if _active_plan is not None:
        raise RuntimeError("a FaultPlan is already active")
    handle = _Active(plan)
    _active_plan = handle
    try:
        yield handle
    finally:
        _active_plan = None


def active() -> bool:
    """Is a plan bound?  (Stores bypass their verified-shard cache when
    injecting, so corruption faults cannot be masked by it.)"""
    return _active_plan is not None


@contextlib.contextmanager
def scope(key):
    """Resolve keyless sites on this thread to ``key`` (e.g. the driver
    wraps each slab's solve so ``recon/solve`` knows its slab index)."""
    prev = getattr(_scope, "key", None)
    _scope.key = key
    try:
        yield
    finally:
        _scope.key = prev


def fire(site: str, key=None, ctx: dict | None = None) -> None:
    """Consult ``site``; may sleep or raise per the active plan."""
    ap = _active_plan
    if ap is None:
        return
    _apply(ap, site, key, ctx, None)


def mutate(site: str, arr, key=None, ctx: dict | None = None):
    """Consult ``site`` with an array in flight; returns it (possibly
    corrupted/poisoned -- always a copy when modified)."""
    ap = _active_plan
    if ap is None:
        return arr
    return _apply(ap, site, key, ctx, arr)


def _apply(ap: _Active, site: str, key, ctx, arr):
    if key is None:
        key = getattr(_scope, "key", None)
    attempt = ap.next_attempt(site, key)
    seed = ap.plan.seed
    for f in ap.plan.faults_at(site):
        if not f.fires(key, attempt, ctx):
            continue
        ap.fired.append((site, key, attempt, f.kind))
        obs_metrics.inc("faults_injected_total", site=site, kind=f.kind)
        obs_trace.instant(
            "resil/fault", site=site, kind=f.kind, key=str(key),
            attempt=attempt,
        )
        if f.kind == "slow":
            time.sleep(f.delay_s)
        elif f.kind in _RAISES:
            raise _RAISES[f.kind](
                f"injected {f.kind} at {site} (key={key!r}, "
                f"attempt={attempt})"
            )
        elif f.kind == "corrupt" and arr is not None:
            arr = _flip(seed, site, key, attempt, arr, f.flip_bytes)
        elif f.kind == "nonfinite" and arr is not None:
            arr = _poison(seed, site, key, attempt, arr)
    return arr


def _flip(seed, site, key, attempt, arr, nbytes: int):
    """Bit-flip ``nbytes`` deterministically chosen bytes of a copy."""
    out = np.array(arr)  # contiguous copy; never mutate the caller's
    buf = out.view(np.uint8).reshape(-1)
    for i in range(nbytes):
        pos = int(hash01(seed, site, key, attempt, i) * buf.size)
        buf[pos % buf.size] ^= 0xFF
    return out

def _poison(seed, site, key, attempt, arr):
    """NaN one deterministically chosen element of a float copy."""
    out = np.array(arr)
    flat = out.reshape(-1)
    flat[int(hash01(seed, site, key, attempt) * flat.size) % flat.size] \
        = np.nan
    return out
