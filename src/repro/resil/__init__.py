"""Resilience layer: deterministic chaos + the recovery it validates.

The paper's regime -- day-long campaigns on up to 24,576 GPUs -- means
partial failure is the steady state, not the exception.  This package
holds both halves of surviving it:

* :mod:`~repro.resil.inject` -- a seeded :class:`FaultPlan` fired at
  named sites (disk reads, prefetch loads, solves, plan builds), pure
  in ``(seed, site, key, attempt)`` and zero-overhead when inactive;
* :mod:`~repro.resil.retry` -- :class:`RetryPolicy` with deterministic
  backoff jitter, driving the streaming driver's and serve path's
  transient-failure recovery;
* :mod:`~repro.resil.circuit` -- a per-``plan_key``
  :class:`CircuitBreaker` for the serve build path;
* :mod:`~repro.resil.errors` -- the typed failures the above dispatch
  on (:class:`CorruptShardError`, :class:`NonFiniteSolveError`, ...).

Depends only on :mod:`repro.obs` (metrics + trace instants), so every
other subsystem can import it without cycles.  See
``docs/fault_tolerance.md`` for the failure model and state machines.
"""
from . import inject
from .circuit import CircuitBreaker
from .errors import (
    CorruptShardError,
    DeadlineExceeded,
    InjectedError,
    InjectedIOError,
    InjectedPreemption,
    InjectedThreadDeath,
    NonFiniteSolveError,
)
from .inject import Fault, FaultPlan
from .retry import RETRYABLE_IO, RetryPolicy, call_with_retry

__all__ = [
    "inject",
    "Fault",
    "FaultPlan",
    "RetryPolicy",
    "RETRYABLE_IO",
    "call_with_retry",
    "CircuitBreaker",
    "CorruptShardError",
    "NonFiniteSolveError",
    "DeadlineExceeded",
    "InjectedIOError",
    "InjectedThreadDeath",
    "InjectedError",
    "InjectedPreemption",
]
