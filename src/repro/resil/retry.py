"""Retry with deterministic exponential backoff + jitter.

:class:`RetryPolicy` is pure configuration: attempts, backoff curve,
per-item wall-clock budget.  Its jitter is *deterministic* -- drawn
from :func:`repro.resil.inject.hash01` over ``(seed, site, key,
attempt)`` -- so a retried chaos scenario replays with identical
timing decisions, and two workers retrying different slabs still
de-synchronize (different keys, different jitter).

>>> p = RetryPolicy(max_attempts=4, base_delay_s=0.1, backoff=2.0,
...                 jitter=0.0, seed=7)
>>> [round(p.delay_s("stream/load", 3, a), 3) for a in (1, 2, 3)]
[0.1, 0.2, 0.4]

:func:`call_with_retry` drives a callable under a policy.  Retryable
classes default to transient I/O (``OSError`` covers the injected read
errors *and* :class:`~repro.resil.errors.CorruptShardError`, plus
``TimeoutError``); a corrupt shard is special-cased to **one** re-read
-- deterministic on-disk corruption will not heal, a torn read might
-- after which the error propagates for the caller to quarantine.
Every retry bumps ``retries_total{site}`` and drops a ``resil/retry``
trace instant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .errors import CorruptShardError
from .inject import hash01

__all__ = ["RetryPolicy", "RETRYABLE_IO", "call_with_retry"]

RETRYABLE_IO = (OSError, TimeoutError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard to try: total attempts, backoff, per-item budget.

    ``max_attempts`` counts the first try (``1`` disables retries);
    ``timeout_s`` bounds the wall clock across all attempts of one item
    (e.g. per slab) -- when the budget is spent, the last error
    propagates even if attempts remain.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    backoff: float = 2.0
    jitter: float = 0.5  # +/- fraction of the nominal delay
    timeout_s: float | None = None
    seed: int = 0

    def delay_s(self, site: str, key, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered."""
        d = self.base_delay_s * self.backoff ** (attempt - 1)
        if self.jitter:
            u = hash01(self.seed, site, key, attempt)
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, d)

    def attempts_for(self, exc: BaseException) -> int:
        """Attempt budget for this failure type (corrupt shard: one
        re-read, then let the caller quarantine)."""
        if isinstance(exc, CorruptShardError):
            return min(2, self.max_attempts)
        return self.max_attempts


def call_with_retry(
    fn: Callable[[int], object],
    *,
    policy: RetryPolicy,
    site: str,
    key=None,
    retryable: tuple = RETRYABLE_IO,
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Callable[[], None] | None = None,
):
    """Run ``fn(attempt)`` under ``policy``; return its first success.

    Non-``retryable`` exceptions propagate immediately (a dead worker
    thread or a solver bug is not something backoff fixes).  When
    attempts or the time budget run out, the *last* exception
    propagates unchanged, so callers keep dispatching on its type.
    """
    t0 = time.monotonic()
    attempt = 0
    while True:
        try:
            return fn(attempt)
        except retryable as e:
            attempt += 1
            out_of_time = (
                policy.timeout_s is not None
                and time.monotonic() - t0 >= policy.timeout_s
            )
            if attempt >= policy.attempts_for(e) or out_of_time:
                raise
            obs_metrics.inc("retries_total", site=site)
            obs_trace.instant(
                "resil/retry", site=site, key=str(key), attempt=attempt,
                error=type(e).__name__,
            )
            if on_retry is not None:
                on_retry()
            d = policy.delay_s(site, key, attempt)
            if d > 0.0:
                sleep(d)
