"""One HBM-traffic model for the blocked-ELL SpMM, shared by every layer.

Historically four call sites hand-rolled the same byte accounting --
``ops.apply_operator`` (staging-chunk sizing), ``benchmarks/bench_spmm``
(arithmetic intensity), ``launch/xct_perf.sweep`` and
``launch/dryrun.xct_analytic`` (roofline memory term) -- and they had
already drifted (the chunk sizing assumed 4-byte windows while windows
are staged in the 2-byte storage dtype).  This module is now the single
source of truth.

Per minibatch of ``F`` fused slices, one device's shard moves:

  operator     B*S*R*K slots x (2 B index + ``sb`` B value)  -- one pass
  descriptors  what the window staging reads to address its copies:
               B*S*BUF window ids x 4 B (per-row DMA path and the
               gather baseline's XLA gather), or B*S*NSEG x 12 B
               ``{src, dst, len}`` segments (coalesced path -- with the
               run-extension slot order NSEG ~ 1.2 BUF**0.6, so this is
               LESS descriptor traffic on top of the issue-count win;
               under the legacy ``slot_order="first_seen"`` layout NSEG
               ~ 0.62 BUF and the segment table was slightly MORE
               descriptor traffic, the price of cutting the issue count;
               both terms are priced honestly)
  window       staging="fused":  B*S*BUF*F*sb  (each window row crosses
               HBM once: DMA'd straight into VMEM by the kernel)
               staging="gather": 2 x B*S*BUF*F*sb  (the XLA gather
               writes the [B, S, BUF, F] tensor to HBM, the kernel reads
               it back -- the extra full pass the fused path deletes)
  band out     B*R*F x 4 B fp32, written by the kernel and read by the
               reduction scatter

Bytes alone do not price the buffer-load loop: every issued copy also
pays a fixed descriptor/issue overhead, which is why the kernel
coalesces run-length segments (one strided copy per run) instead of
copying row by row.  ``dma_issues`` counts the copies and
:func:`dma_issue_seconds` prices the whole transfer as

    t = issues * per_copy_overhead + bytes / bandwidth

Doctest -- the fused path strictly raises arithmetic intensity (the
acceptance criterion of the in-kernel-staging refactor; both at
``dma="per_row"`` so the descriptor terms match):

>>> g = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                  staging="gather", dma="per_row")
>>> u = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                  staging="fused", dma="per_row")
>>> u["hbm_bytes"] < g["hbm_bytes"]
True
>>> u["intensity"] > g["intensity"]
True
>>> g["hbm_bytes"] - u["hbm_bytes"] == g["window_bytes"] // 2
True

and coalescing strictly drops the modeled issue count (the acceptance
criterion of the coalesced-DMA refactor); slot reordering drops it
further still (the acceptance criterion of the run-extension layout):

>>> c = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2)
>>> c["dma_issues"] < u["dma_issues"]
True
>>> u["dma_issues"] == 8 * 2 * 768.0
True
>>> c["winmap_bytes"] == 8 * 2 * est_segments_per_stage(768) * 12.0
True
>>> legacy = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                       slot_order="first_seen")
>>> c["dma_issues"] < legacy["dma_issues"]
True

Quantized operator values (``vals_bytes=1``: int8/fp8 + the int32
per-(block, stage) scale table) shrink the dominant operator stream --
3 B/nnz slot vs 4 B at f16 -- and raise intensity accordingly:

>>> q = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                  vals_bytes=1)
>>> q["operator_bytes"] == 8 * 2 * 64 * 64 * 3.0 + 8 * 2 * 4.0
True
>>> q["operator_bytes"] < c["operator_bytes"]
True
>>> q["intensity"] > c["intensity"]
True
"""
from __future__ import annotations

import math

__all__ = [
    "spmm_traffic",
    "staged_window_bytes",
    "dma_issue_seconds",
    "est_segments_per_stage",
    "op_segments_per_stage",
    "DMA_MODES",
    "PER_COPY_OVERHEAD_S",
]

STAGINGS = ("fused", "gather")
DMA_MODES = ("coalesced", "per_row")

# Fixed cost of issuing one async copy (descriptor setup + DMA engine
# dispatch).  A model parameter, O(100 ns) class on current parts -- the
# same order as the CUDA per-load index overhead Listing 1's buffer-load
# loop amortizes.  At F=16 a per-row window copy moves only ~32 B, so
# the staging loop is issue-bound at ANY plausible overhead; the sweeps
# expose exactly that (and what run-length coalescing claws back).
PER_COPY_OVERHEAD_S = 1e-7


def staged_window_bytes(s: int, buf: int, f: int,
                        storage_bytes: int) -> int:
    """Transient HBM bytes of ONE row-block's gathered windows.

    Only the legacy gather path allocates this ``[S, BUF, F]`` tensor
    (per row-block of the scan chunk); the fused kernel's staging lives
    in VMEM (see ``xct_spmm.vmem_bytes``).
    """
    return s * buf * f * storage_bytes


def est_segments_per_stage(buf: int, slot_order: str = "runs") -> int:
    """Analytic decomposed-segment count for one stage's window.

    For abstract plans (``estimate_plan``) no winmap exists to run-length
    encode, so the sweeps need a model.  The count depends on the plan's
    ``slot_order`` (see ``core.partition.PartitionConfig``):

    ``"runs"``
        Slots are assigned by greedy run extension over the
        Hilbert-sorted column set, so winmap entries form long
        ``{src, dst, len}`` runs and the segment count grows sublinearly
        with the window: measured means on built plans at n in [32, 64]
        sit on ``~1.2 x BUF**0.6`` (8 plan shapes, BUF 72-424, est/real
        in [0.5, 2] pinned by ``tests/test_kernel_spmm.py::
        test_est_segments_calibrated``).

    ``"first_seen"``
        Legacy CSR-position layout: a stage samples its columns strided
        (slot position, not curve position), so runs stay short --
        measured means are 0.40-0.75 x BUF; the model uses the measured
        mid-band 0.62 x BUF.
    """
    if slot_order == "first_seen":
        return int(min(buf, max(1, math.ceil(0.62 * buf))))
    if slot_order != "runs":
        raise ValueError(
            f"unknown slot_order {slot_order!r}; one of ('runs', 'first_seen')"
        )
    return int(min(buf, max(1, math.ceil(1.2 * buf ** 0.6))))


def op_segments_per_stage(op) -> float | None:
    """Segments-per-stage of an operator shard, for the issue model.

    Real shards carry ``winsegs`` tables (``ops.winmap_segments``): the
    *measured mean* non-pad segment count per stage.  Abstract shards
    (``estimate_plan``) carry only the table shape: its capacity, which
    came from :func:`est_segments_per_stage`.  Returns ``None`` when the
    operator predates the tables (falls back to the analytic model).
    """
    ws = getattr(op, "winsegs", None)
    if ws is None:
        return None
    try:
        import numpy as _np

        arr = _np.asarray(ws)
    except TypeError:  # ShapeDtypeStruct and friends
        return float(ws.shape[-2])
    if arr.dtype == object or arr.ndim < 2:
        return float(ws.shape[-2])
    return float((arr[..., 2] > 0).sum(axis=-1).mean())


def dma_issue_seconds(
    issues: float,
    bytes_: float,
    bandwidth: float,
    per_copy_overhead: float = PER_COPY_OVERHEAD_S,
) -> float:
    """Seconds to move ``bytes_`` in ``issues`` async copies:
    ``issues x per_copy_overhead + bytes / bandwidth``.  The first term
    is what run-length coalescing shrinks (issues: B*S*BUF per-row ->
    B*S*NSEG) without touching the second."""
    return float(issues) * per_copy_overhead + float(bytes_) / bandwidth


def spmm_traffic(
    b: int,
    s: int,
    r: int,
    k: int,
    buf: int,
    f: int,
    *,
    storage_bytes: int = 2,
    vals_bytes: int | None = None,
    staging: str = "fused",
    dma: str = "coalesced",
    segments_per_stage: float | None = None,
    slot_order: str = "runs",
    interpret_timed: bool = False,
) -> dict:
    """HBM bytes + FLOPs of one fused-minibatch SpMM over one shard.

    Returns a dict with the per-term byte counts, their sum
    (``hbm_bytes``), the slot FLOPs (``flops`` = 2 per nnz slot per
    slice), the arithmetic intensity (``intensity``, FLOP/B), and the
    DMA issue count of the window staging (``dma_issues``): one copy
    per winmap row (``dma="per_row"``), one per run-length segment
    (``dma="coalesced"``; measured ``segments_per_stage`` from
    ``ops.winmap_segments`` when available, else the analytic
    :func:`est_segments_per_stage` for the plan's ``slot_order``), or
    one BlockSpec tile per stage for the gather baseline (XLA stages
    its windows in bulk).

    ``vals_bytes`` is the width of the packed operator *values*
    (``Precision.vals_bytes``); ``None`` means same as the vector
    ``storage_bytes`` (every pre-quantization policy).  A 1-byte width
    adds the int32 per-(block, stage) dequantization-scale table to the
    descriptor stream (4 B per stage -- the scales ride scalar
    prefetch, but they still cross HBM once).

    ``interpret_timed=True`` declares that any wall-clock numbers the
    caller plans to compare against this model came from Pallas
    interpret mode, where async copies are emulated element loops and
    per-copy overhead is an artifact of the emulator, not the DMA
    engine.  The model warns once per call: do not RANK dma modes on
    interpret timings -- :func:`dma_issue_seconds` over the modeled
    issue counts is the authority (the autotuner's modeled tier does
    exactly that).
    """
    if staging not in STAGINGS:
        raise ValueError(
            f"unknown staging {staging!r}; one of {STAGINGS}"
        )
    if dma not in DMA_MODES:
        raise ValueError(f"unknown dma {dma!r}; one of {DMA_MODES}")
    if interpret_timed:
        import warnings

        warnings.warn(
            "spmm_traffic: timings taken in Pallas interpret mode emulate "
            "async copies as element loops -- per-copy cost there is an "
            "emulator artifact.  Do not rank dma modes on those timings; "
            "use dma_issue_seconds over the modeled issue counts instead.",
            RuntimeWarning,
            stacklevel=2,
        )
    slots = float(b) * s * r * k
    win_entries = float(b) * s * buf
    passes = 1 if staging == "fused" else 2
    seg = (
        float(segments_per_stage)
        if segments_per_stage is not None
        else float(est_segments_per_stage(buf, slot_order))
    )
    if staging == "gather":
        issues = float(b) * s  # one [BUF, F] BlockSpec tile per stage
        desc_bytes = win_entries * 4  # XLA gather reads the winmap
    elif dma == "per_row":
        issues = win_entries
        desc_bytes = win_entries * 4  # int32 winmap prefetch
    else:
        issues = float(b) * s * seg
        desc_bytes = float(b) * s * seg * 12  # {src, dst, len} int32
    vb = storage_bytes if vals_bytes is None else vals_bytes
    scale_bytes = float(b) * s * 4 if vb == 1 else 0.0
    out = {
        "operator_bytes": slots * (2 + vb) + scale_bytes,
        "winmap_bytes": desc_bytes,
        "window_bytes": win_entries * storage_bytes * f * passes,
        "out_bytes": float(b) * r * f * 4 * 2,
        "flops": 2.0 * slots * f,
        "dma_issues": issues,
    }
    out["hbm_bytes"] = (
        out["operator_bytes"] + out["winmap_bytes"]
        + out["window_bytes"] + out["out_bytes"]
    )
    out["intensity"] = out["flops"] / out["hbm_bytes"]
    return out
