"""One HBM-traffic model for the blocked-ELL SpMM, shared by every layer.

Historically four call sites hand-rolled the same byte accounting --
``ops.apply_operator`` (staging-chunk sizing), ``benchmarks/bench_spmm``
(arithmetic intensity), ``launch/xct_perf.sweep`` and
``launch/dryrun.xct_analytic`` (roofline memory term) -- and they had
already drifted (the chunk sizing assumed 4-byte windows while windows
are staged in the 2-byte storage dtype).  This module is now the single
source of truth.

Per minibatch of ``F`` fused slices, one device's shard moves:

  operator     B*S*R*K slots x (2 B index + ``sb`` B value)  -- one pass
  winmap       B*S*BUF window ids x 4 B
  window       staging="fused":  B*S*BUF*F*sb  (each window row crosses
               HBM once: DMA'd straight into VMEM by the kernel)
               staging="gather": 2 x B*S*BUF*F*sb  (the XLA gather
               writes the [B, S, BUF, F] tensor to HBM, the kernel reads
               it back -- the extra full pass the fused path deletes)
  band out     B*R*F x 4 B fp32, written by the kernel and read by the
               reduction scatter

Doctest -- the fused path strictly raises arithmetic intensity (the
acceptance criterion of the in-kernel-staging refactor):

>>> g = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                  staging="gather")
>>> u = spmm_traffic(8, 2, 64, 64, 768, 16, storage_bytes=2,
...                  staging="fused")
>>> u["hbm_bytes"] < g["hbm_bytes"]
True
>>> u["intensity"] > g["intensity"]
True
>>> g["hbm_bytes"] - u["hbm_bytes"] == g["window_bytes"] // 2
True
"""
from __future__ import annotations

__all__ = ["spmm_traffic", "staged_window_bytes"]

STAGINGS = ("fused", "gather")


def staged_window_bytes(s: int, buf: int, f: int,
                        storage_bytes: int) -> int:
    """Transient HBM bytes of ONE row-block's gathered windows.

    Only the legacy gather path allocates this ``[S, BUF, F]`` tensor
    (per row-block of the scan chunk); the fused kernel's staging lives
    in VMEM (see ``xct_spmm.vmem_bytes``).
    """
    return s * buf * f * storage_bytes


def spmm_traffic(
    b: int,
    s: int,
    r: int,
    k: int,
    buf: int,
    f: int,
    *,
    storage_bytes: int = 2,
    staging: str = "fused",
) -> dict:
    """HBM bytes + FLOPs of one fused-minibatch SpMM over one shard.

    Returns a dict with the per-term byte counts, their sum
    (``hbm_bytes``), the slot FLOPs (``flops`` = 2 per nnz slot per
    slice) and the arithmetic intensity (``intensity``, FLOP/B).
    """
    if staging not in STAGINGS:
        raise ValueError(
            f"unknown staging {staging!r}; one of {STAGINGS}"
        )
    slots = float(b) * s * r * k
    win_entries = float(b) * s * buf
    passes = 1 if staging == "fused" else 2
    out = {
        "operator_bytes": slots * (2 + storage_bytes),
        "winmap_bytes": win_entries * 4,
        "window_bytes": win_entries * storage_bytes * f * passes,
        "out_bytes": float(b) * r * f * 4 * 2,
        "flops": 2.0 * slots * f,
    }
    out["hbm_bytes"] = (
        out["operator_bytes"] + out["winmap_bytes"]
        + out["window_bytes"] + out["out_bytes"]
    )
    out["intensity"] = out["flops"] / out["hbm_bytes"]
    return out
