"""jit'd wrappers around the XCT SpMM kernel.

``apply_operator`` is the single-device (shard-local) fused
projection/backprojection.  The default path (``staging="fused"``) hands
the whole local slab to the Pallas kernel, which streams each stage's
window from HBM into VMEM itself (the paper's Listing 1 buffer-load
loop) -- one HBM pass over operator data per minibatch, no staged window
tensor, no transient-budget chunking.

``staging="gather"`` keeps the legacy two-pass emulation for A/B
benchmarking: an XLA gather materializes the ``[B, S, BUF, F]`` windows
in HBM before the kernel runs, bounded by a ~64 MB transient budget
(chunked over row-blocks with ``lax.scan``).  The oracle equivalent
lives in ``ref.py``; ``use_ref=True`` swaps it in so every higher layer
can be validated against pure jnp with one flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .traffic import STAGINGS, staged_window_bytes
from .xct_spmm import spmm_block_ell, spmm_block_ell_staged

__all__ = ["apply_operator"]


def _gather_blocks_per_call(b, s, buf, f, bytes_per, budget=64 << 20):
    """Row-blocks whose gathered windows fit a ~64 MB transient budget.

    Only the legacy gather path needs this: it materializes
    ``[bpc, S, BUF, F]`` windows per inner-scan step in the *storage*
    dtype (``bytes_per`` is that dtype's itemsize -- sizing from 4 bytes
    under-chunked by 2x in half/mixed modes).  Must divide ``b`` (B is
    padded to a multiple of 8 by the partitioner).
    """
    per_block = staged_window_bytes(s, buf, f, bytes_per)
    want = max(1, budget // max(1, per_block))
    if want >= b:
        return b
    for d in range(min(want, b), 0, -1):
        if b % d == 0:
            return d
    return 1


def apply_operator(
    inds,
    vals,
    winmap,
    x_loc,
    *,
    storage_dtype=jnp.float16,
    compute_dtype=jnp.float32,
    use_ref: bool = False,
    interpret: bool | None = None,
    staging: str = "fused",
    blocks_per_call: int | None = None,
):
    """Shard-local fused SpMM: returns the fp32 partial rows [B*R, F].

    Args:
      inds: [B, S, R, K] int16 window-local indices.
      vals: [B, S, R, K] float32 master lengths (cast to ``storage_dtype``
        here -- the 2-byte HBM representation of the paper's packing --
        unless already narrow).
      winmap: [B, S, BUF] device-local input column ids.
      x_loc: [C, F] local input slab (any float dtype; cast to
        ``storage_dtype``, computed in ``compute_dtype``).
      staging: "fused" (default) stages windows inside the kernel --
        double-buffered HBM->VMEM copies, no intermediate tensor;
        "gather" is the legacy two-pass XLA-gather path (A/B baseline).
      blocks_per_call: [deprecated -- only the gather path chunks]
        row-blocks per inner scan step; auto-sized when None.
    """
    if staging not in STAGINGS:
        raise ValueError(
            f"unknown staging {staging!r}; one of {STAGINGS}"
        )
    vals_s = vals.astype(storage_dtype)
    x_s = x_loc.astype(storage_dtype)
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x_loc.shape[-1]

    if use_ref:
        return ref.spmm_ref(
            inds, vals_s, winmap, x_s, compute_dtype=compute_dtype
        ).astype(jnp.float32)

    if staging == "fused":
        out = spmm_block_ell(
            inds, vals_s, winmap, x_s,
            compute_dtype=compute_dtype, interpret=interpret,
        )
        return out.reshape(b * r, f)

    # --- legacy gather staging (A/B benchmarking baseline) -------------
    def one_chunk(ic, vc, wc):
        window = jnp.take(x_s, wc, axis=0)  # staging gather (HBM)
        return spmm_block_ell_staged(
            ic, vc, window, compute_dtype=compute_dtype,
            interpret=interpret,
        )

    bpc = blocks_per_call or _gather_blocks_per_call(
        b, s, buf, f, jnp.dtype(storage_dtype).itemsize
    )
    if bpc >= b:
        return one_chunk(inds, vals_s, winmap).reshape(b * r, f)

    n_chunk = b // bpc

    def step(_, args):
        return None, one_chunk(*args)

    _, outs = jax.lax.scan(
        step,
        None,
        (
            inds.reshape(n_chunk, bpc, s, r, k),
            vals_s.reshape(n_chunk, bpc, s, r, k),
            winmap.reshape(n_chunk, bpc, s, buf),
        ),
    )
    return outs.reshape(b * r, f)
