"""jit'd wrappers around the XCT SpMM kernel + the window-DMA builder.

``apply_operator`` is the single-device (shard-local) fused
projection/backprojection.  The default path (``staging="fused"``,
``dma="coalesced"``) hands the whole local slab to the Pallas kernel,
which streams each stage's window from HBM into VMEM itself (the
paper's Listing 1 buffer-load loop) -- one HBM pass over operator data
per minibatch, no staged window tensor, no transient-budget chunking --
and issues one strided copy per *run-length segment* of consecutive
source rows instead of one per row (``winmap_segments`` below;
Hilbert-ordered columns make the runs long, so DMA issue overhead is
amortized like Listing 1 amortizes index loads).

``staging="gather"`` keeps the legacy two-pass emulation for A/B
benchmarking: an XLA gather materializes the ``[B, S, BUF, F]`` windows
in HBM before the kernel runs, bounded by a ~64 MB transient budget
(chunked over row-blocks with ``lax.scan``).  ``dma="per_row"`` keeps
the one-copy-per-window-row fused path for the same purpose.  The
oracle equivalent lives in ``ref.py``; ``use_ref=True`` swaps it in so
every higher layer can be validated against pure jnp with one flag.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import ref
from .traffic import DMA_MODES, STAGINGS, staged_window_bytes
from .xct_spmm import _dma_classes, spmm_block_ell, spmm_block_ell_staged

__all__ = [
    "apply_operator",
    "winmap_segments",
    "sort_segments_by_class",
    "segment_histogram",
    "dma_issue_count",
]


def winmap_segments(winmap, pad_to: int = 8) -> np.ndarray:
    """Run-length encode a ``[..., BUF]`` winmap into DMA segments.

    Every maximal run of *consecutive* source rows in a stage's window
    (``winmap[..., j+1] == winmap[..., j] + 1``) becomes one coalesced
    copy ``x[src : src+len] -> win[dst : dst+len]``; runs are then split
    into power-of-two pieces (largest first) because Pallas DMA extents
    are static -- the kernel unrolls over the possible length classes
    and issues each piece with one ``pl.when``-guarded copy.  Hilbert
    ordering (``core.partition``) keeps runs long, so a production
    stage's window moves in O(NSEG) issues instead of O(BUF).

    Args:
      winmap: ``[..., BUF]`` int array of device-local input column ids
        (any leading batch dims; the shards use ``[B, S, BUF]``).
      pad_to: pad the per-stage segment capacity to a multiple of this.

    Returns:
      ``[..., NSEG, 3]`` int32: ``{src_start, dst_start, len}`` per
      segment, ``len`` a power of two; pad slots have ``len == 0`` (the
      kernel skips them).  NSEG is the max decomposed-segment count over
      all leading indices, padded to ``pad_to``.
    """
    wm = np.asarray(winmap)
    if wm.ndim < 1:
        raise ValueError("winmap must have a trailing BUF dimension")
    lead, buf = wm.shape[:-1], wm.shape[-1]
    flat = wm.reshape(-1, buf).astype(np.int64)
    n = flat.shape[0]
    if n == 0:
        return np.zeros((*lead, pad_to, 3), np.int32)
    # fully vectorized (plan builds call this for every shard): run
    # boundaries, then one fill pass per power-of-two length class
    isbrk = np.ones((n, buf), bool)
    if buf > 1:
        isbrk[:, 1:] = np.diff(flat, axis=1) != 1
    row_id, st = np.nonzero(isbrk)  # runs, row-major order
    en = np.empty_like(st)
    en[:-1] = st[1:]
    en[-1] = buf
    en[np.flatnonzero(np.diff(row_id))] = buf  # last run of each row
    length = en - st
    src0 = flat[row_id, st]
    nbits = int(buf).bit_length()
    counts = np.zeros_like(length)  # popcount = decomposed pieces/run
    for b in range(nbits):
        counts += (length >> b) & 1
    # piece slot = (pieces of prior runs in the row) + (larger pieces
    # of this run): largest-first order, matching the kernel's classes
    cum = np.cumsum(counts) - counts
    firsts = np.concatenate(([0], np.flatnonzero(np.diff(row_id)) + 1))
    runs_per_row = np.diff(np.append(firsts, row_id.size))
    run_off = cum - np.repeat(cum[firsts], runs_per_row)
    totals = np.add.reduceat(counts, firsts)
    nseg = pad_to * -(-int(totals.max()) // pad_to)
    out = np.zeros((n, nseg, 3), np.int32)
    for b in range(nbits):
        sel = ((length >> b) & 1) == 1
        if not sel.any():
            continue
        ln = length[sel]
        off = (ln >> (b + 1)) << (b + 1)  # sum of the larger pieces
        rank = np.zeros_like(ln)
        for b2 in range(b + 1, nbits):
            rank += (ln >> b2) & 1
        slot = run_off[sel] + rank
        out[row_id[sel], slot, 0] = src0[sel] + off
        out[row_id[sel], slot, 1] = st[sel] + off
        out[row_id[sel], slot, 2] = 1 << b
    return out.reshape(*lead, nseg, 3)


def sort_segments_by_class(
    winsegs, buf: int
) -> tuple[np.ndarray, np.ndarray]:
    """Sort every stage's segment table by descending copy length and
    build the per-class offset table the fused kernel consumes.

    ``winmap_segments`` emits power-of-two pieces in run order; the
    kernel, whose DMA extents must be static, would then have to test
    every slot against every length class (O(classes x NSEG) issue work
    per window -- the interpret-mode 10x inversion ``bench_spmm``
    measured).  Grouping slots by class instead lets the kernel run one
    ``fori_loop`` per class with *dynamic bounds* ``[off[c], off[c+1])``
    over exactly that class's slots: total issue work is O(real
    segments), unconditionally.

    Args:
      winsegs: ``[..., NSEG, 3]`` table from :func:`winmap_segments`.
      buf: the window height (``winmap.shape[-1]``) -- fixes the static
        class list ``xct_spmm._dma_classes(buf)`` the offsets index.

    Returns:
      ``(sorted_segs [..., NSEG, 3], offsets [..., NCLS+1])`` int32:
      slots ``[offsets[i], offsets[i+1])`` hold exactly the segments of
      length ``classes_desc[i]`` (classes in descending order);
      ``offsets[-1]`` ends the real segments, pad slots (len 0) follow.
    """
    segs = np.asarray(winsegs)
    lead, nseg = segs.shape[:-2], segs.shape[-2]
    flat = segs.reshape(-1, nseg, 3)
    order = np.argsort(-flat[..., 2], axis=1, kind="stable")
    srt = np.take_along_axis(flat, order[..., None], axis=1)
    classes = _dma_classes(buf)[::-1]
    lens = srt[..., 2]
    off = np.empty((flat.shape[0], len(classes) + 1), np.int32)
    for i, ln in enumerate(classes):
        off[:, i] = (lens > ln).sum(axis=1)
    off[:, -1] = (lens > 0).sum(axis=1)
    return (
        srt.astype(np.int32).reshape(*lead, nseg, 3),
        off.reshape(*lead, len(classes) + 1),
    )


def dma_issue_count(winsegs) -> int:
    """Copies the coalesced kernel issues per window pass: one per
    non-pad segment (pad slots have ``len == 0``)."""
    return int((np.asarray(winsegs)[..., 2] > 0).sum())


def segment_histogram(winsegs) -> dict:
    """``{copy_len: count}`` over the non-pad segments of a table --
    the measured segments-per-stage histogram ``bench_spmm`` reports."""
    lens = np.asarray(winsegs)[..., 2].ravel()
    lens = lens[lens > 0]
    uniq, cnt = np.unique(lens, return_counts=True)
    return {int(u): int(c) for u, c in zip(uniq, cnt)}


def _gather_blocks_per_call(b, s, buf, f, bytes_per, budget=64 << 20):
    """Row-blocks whose gathered windows fit a ~64 MB transient budget.

    Only the legacy gather path needs this: it materializes
    ``[bpc, S, BUF, F]`` windows per inner-scan step in the *storage*
    dtype (``bytes_per`` is that dtype's itemsize -- sizing from 4 bytes
    under-chunked by 2x in half/mixed modes).  Must divide ``b`` (B is
    padded to a multiple of 8 by the partitioner).
    """
    per_block = staged_window_bytes(s, buf, f, bytes_per)
    want = max(1, budget // max(1, per_block))
    if want >= b:
        return b
    for d in range(min(want, b), 0, -1):
        if b % d == 0:
            return d
    return 1


def apply_operator(
    inds,
    vals,
    winmap,
    x_loc,
    *,
    storage_dtype=jnp.float16,
    compute_dtype=jnp.float32,
    use_ref: bool = False,
    interpret: bool | None = None,
    staging: str = "fused",
    dma: str = "coalesced",
    winsegs=None,
    segoff=None,
    smem_budget: int | None = None,
    blocks_per_call: int | None = None,
    scales=None,
):
    """Shard-local fused SpMM: returns the fp32 partial rows [B*R, F].

    Args:
      inds: [B, S, R, K] int16 window-local indices.
      vals: [B, S, R, K] float32 master lengths (cast to ``storage_dtype``
        here -- the 2-byte HBM representation of the paper's packing --
        unless already narrow).
      winmap: [B, S, BUF] device-local input column ids.
      x_loc: [C, F] local input slab (any float dtype; cast to
        ``storage_dtype``, computed in ``compute_dtype``).
      staging: "fused" (default) stages windows inside the kernel --
        double-buffered HBM->VMEM copies, no intermediate tensor;
        "gather" is the legacy two-pass XLA-gather path (A/B baseline).
      dma: "coalesced" (default) issues one strided copy per run-length
        segment of the winmap; "per_row" keeps the one-copy-per-row
        A/B baseline.  Fused staging only.
      winsegs: precomputed ``winmap_segments(winmap)``; required when
        ``winmap`` is a traced value (e.g. inside ``shard_map`` --
        ``OperatorShards.winsegs`` carries it), computed here otherwise.
      segoff: per-class offsets into a class-sorted ``winsegs`` (from
        ``sort_segments_by_class``; ``OperatorShards.segoff``).  When
        given, the kernel loops each length class over exactly its own
        slots (O(segments) issue work); when omitted with a concrete
        ``winmap``, both tables are built here; a traced ``winsegs``
        without ``segoff`` falls back to the per-slot class-test kernel.
      smem_budget: per-call SMEM budget for the scalar prefetch; the
        kernel chunks row-blocks to fit (see ``xct_spmm``).
      blocks_per_call: [deprecated -- only the gather path chunks]
        row-blocks per inner scan step; auto-sized when None.
      scales: [B, S] int32 per-block dequantization exponents
        (``core.precision.quantize_block_vals``).  When given, ``vals``
        is already-packed int8/fp8 and is passed through untouched; the
        fused kernel dequantizes inline in its FMA loop, the ref/gather
        paths widen to f32 up front (same arithmetic, one extra HBM
        round trip -- A/B baselines only).
    """
    if staging not in STAGINGS:
        raise ValueError(
            f"unknown staging {staging!r}; one of {STAGINGS}"
        )
    if dma not in DMA_MODES:
        raise ValueError(f"unknown dma {dma!r}; one of {DMA_MODES}")
    quantized = scales is not None
    vals_s = vals if quantized else vals.astype(storage_dtype)
    x_s = x_loc.astype(storage_dtype)
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x_loc.shape[-1]

    if quantized and (use_ref or staging != "fused"):
        from repro.core.precision import dequantize_block_vals

        vals_s = dequantize_block_vals(vals, scales, jnp.float32)

    if use_ref:
        return ref.spmm_ref(
            inds, vals_s, winmap, x_s, compute_dtype=compute_dtype
        ).astype(jnp.float32)

    if staging == "fused":
        if dma == "coalesced" and winsegs is None:
            try:
                winsegs, segoff = sort_segments_by_class(
                    winmap_segments(winmap), buf
                )
            except jax.errors.TracerArrayConversionError as e:
                raise ValueError(
                    "dma='coalesced' under tracing needs precomputed "
                    "segments: pass winsegs=winmap_segments(winmap) "
                    "(OperatorShards.winsegs carries them per shard)"
                ) from e
        out = spmm_block_ell(
            inds, vals_s, winmap, x_s,
            compute_dtype=compute_dtype, interpret=interpret,
            winsegs=winsegs if dma == "coalesced" else None,
            segoff=segoff if dma == "coalesced" else None,
            smem_budget=smem_budget,
            scales=scales,
        )
        return out.reshape(b * r, f)

    # --- legacy gather staging (A/B benchmarking baseline) -------------
    def one_chunk(ic, vc, wc):
        window = jnp.take(x_s, wc, axis=0)  # staging gather (HBM)
        return spmm_block_ell_staged(
            ic, vc, window, compute_dtype=compute_dtype,
            interpret=interpret,
        )

    bpc = blocks_per_call or _gather_blocks_per_call(
        b, s, buf, f, jnp.dtype(storage_dtype).itemsize
    )
    if bpc >= b:
        return one_chunk(inds, vals_s, winmap).reshape(b * r, f)

    n_chunk = b // bpc

    def step(_, args):
        return None, one_chunk(*args)

    _, outs = jax.lax.scan(
        step,
        None,
        (
            inds.reshape(n_chunk, bpc, s, r, k),
            vals_s.reshape(n_chunk, bpc, s, r, k),
            winmap.reshape(n_chunk, bpc, s, buf),
        ),
    )
    return outs.reshape(b * r, f)
