"""jit'd wrappers around the XCT SpMM kernel.

``apply_operator`` is the single-device (shard-local) fused
projection/backprojection: window staging (the XLA gather standing in for
Listing 1's buffer-load loop) followed by the Pallas kernel.  The oracle
equivalent lives in ``ref.py``; ``use_ref=True`` swaps it in so every higher
layer can be validated against pure jnp with one flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .xct_spmm import spmm_block_ell

__all__ = ["apply_operator"]


def _pick_blocks_per_call(b, s, buf, f, bytes_per, budget=64 << 20):
    """Blocks whose staged windows fit a ~64 MB transient HBM budget.

    The staging gather materializes [bpc, S, BUF, F] windows per inner-scan
    step; bounding it keeps peak memory O(budget) instead of O(B) (the
    paper's I/O-batch discipline applied to the buffer loads).  Must divide
    ``b`` (B is padded to a multiple of 8 by the partitioner).
    """
    per_block = s * buf * f * bytes_per
    want = max(1, budget // max(1, per_block))
    if want >= b:
        return b
    for d in range(min(want, b), 0, -1):
        if b % d == 0:
            return d
    return 1


def apply_operator(
    inds,
    vals,
    winmap,
    x_loc,
    *,
    storage_dtype=jnp.float16,
    compute_dtype=jnp.float32,
    use_ref: bool = False,
    interpret: bool | None = None,
    blocks_per_call: int | None = None,
):
    """Shard-local fused SpMM: returns the fp32 partial rows [B*R, F].

    Args:
      inds: [B, S, R, K] int16 window-local indices.
      vals: [B, S, R, K] float32 master lengths (cast to ``storage_dtype``
        here -- the 2-byte HBM representation of the paper's packing --
        unless already narrow).
      winmap: [B, S, BUF] device-local input column ids.
      x_loc: [C, F] local input slab (any float dtype; staged to
        ``storage_dtype`` for the VMEM window, computed in
        ``compute_dtype``).
      blocks_per_call: row-blocks per inner scan step (bounds the transient
        window-staging buffer); auto-sized when None.
    """
    vals_s = vals.astype(storage_dtype)
    x_s = x_loc.astype(storage_dtype)
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x_loc.shape[-1]

    def one_chunk(ic, vc, wc):
        if use_ref:
            out = ref.spmm_ref(
                ic, vc, wc, x_s, compute_dtype=compute_dtype
            ).astype(jnp.float32)
            return out.reshape(ic.shape[0], r, f)
        window = jnp.take(x_s, wc, axis=0)  # staging gather (HBM)
        return spmm_block_ell(
            ic, vc, window, compute_dtype=compute_dtype,
            interpret=interpret,
        )

    bpc = blocks_per_call or _pick_blocks_per_call(
        b, s, max(buf, r * k), f, 4
    )
    if bpc >= b:
        return one_chunk(inds, vals_s, winmap).reshape(b * r, f)

    n_chunk = b // bpc

    def step(_, args):
        return None, one_chunk(*args)

    _, outs = jax.lax.scan(
        step,
        None,
        (
            inds.reshape(n_chunk, bpc, s, r, k),
            vals_s.reshape(n_chunk, bpc, s, r, k),
            winmap.reshape(n_chunk, bpc, s, buf),
        ),
    )
    return outs.reshape(b * r, f)
