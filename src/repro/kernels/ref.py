"""Pure-jnp oracles for the XCT blocked-ELL SpMM.

Two oracles:

  * :func:`spmm_ref` -- operates on the exact blocked-ELL shard layout the
    Pallas kernel consumes (same staging, same padding).  Used for
    kernel-vs-oracle allclose sweeps.
  * :func:`coo_apply` -- operates on the raw COO triplets of the original
    (un-permuted) system matrix.  Used for end-to-end system checks
    (partitioning + permutation + kernel + reduction == plain SpMM).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["spmm_ref", "coo_apply"]


def spmm_ref(inds, vals, winmap, x_loc, *, compute_dtype=jnp.float32):
    """Reference fused SpMM over one device's blocked-ELL shard.

    Args:
      inds:   [B, S, R, K] window-local indices (any int dtype).
      vals:   [B, S, R, K] lengths (any float dtype).
      winmap: [B, S, BUF]  device-local input column ids.
      x_loc:  [C, F] local input slab (C = padded local columns, F = fused
              slices, the paper's minibatch/FFACTOR dimension).

    Returns:
      [B * R, F] partial output band in ``compute_dtype``.
    """
    b, s, r, k = inds.shape
    f = x_loc.shape[-1]
    window = jnp.take(x_loc, winmap, axis=0).astype(compute_dtype)  # B,S,BUF,F
    flat = inds.reshape(b, s, r * k).astype(jnp.int32)
    g = jnp.take_along_axis(window, flat[..., None], axis=2)  # B,S,R*K,F
    g = g.reshape(b, s, r, k, f)
    acc = (vals.astype(compute_dtype)[..., None] * g).sum(axis=(1, 3))
    return acc.reshape(b * r, f)


def coo_apply(rows, cols, lens, x, n_rows, *, compute_dtype=jnp.float32):
    """Plain COO SpMM: ``y[rows] += lens * x[cols]`` broadcast over slices.

    Args:
      rows, cols, lens: COO triplets of the (dense-index) system matrix.
      x: [n_cols, F] input slabs.
      n_rows: output row count.
    """
    contrib = lens.astype(compute_dtype)[:, None] * x[cols].astype(
        compute_dtype
    )
    y = jnp.zeros((n_rows, x.shape[1]), compute_dtype)
    return y.at[rows].add(contrib)
