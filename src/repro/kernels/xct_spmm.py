"""XCT-optimized fused SpMM as a Pallas TPU kernel.

TPU re-derivation of the paper's Listing 1 (Sec. III-B), including the
*buffer-load loop* (lines 15-20): the kernel itself streams each stage's
window of input rows from HBM into on-chip memory, so no staged window
tensor ever exists in HBM.  The CUDA kernel's mechanisms map as follows:

  =============================  =======================================
  Listing 1 (CUDA)               this kernel (Pallas TPU)
  =============================  =======================================
  shared-memory 3D input buffer  VMEM scratch ``win[2, BUF, F]``
  buffer-load loop (l. 15-20)    async DMAs HBM -> VMEM, driven by the
                                 scalar-prefetched window descriptors
                                 (SMEM, ``PrefetchScalarGridSpec``):
                                 one copy per run-length *segment* of
                                 consecutive source rows (default), or
                                 one per row (``winsegs=None`` A/B)
  coalesced gmem loads           ``ops.winmap_segments`` run-length
                                 encodes the winmap host-side (Hilbert
                                 ordering makes runs long); each segment
                                 is one strided multi-row copy, so DMA
                                 issue overhead is amortized the same
                                 way Listing 1 amortizes index loads
  multi-stage buffering          second grid dimension ``s``; the output
                                 block is revisited across stages and
                                 accumulated in fp32 (TPU grids execute
                                 sequentially over revisited blocks)
  __syncthreads() double-buffer  two window slots + DMA semaphores:
                                 stage ``n+1``'s loads are issued before
                                 stage ``n``'s FMAs run (overlap)
  register reuse across FFACTOR  the fused-slice dim ``F`` is the minor
                                 (lane) dimension; one {index, len} pair
                                 drives an F-wide VPU FMA
  {uint16, half} 4-byte packing  int16 index tile + fp16/bf16 value tile
                                 (4 B/nnz in HBM); upcast in-VREG
  fp32 FMA on fp16 data          explicit astype(compute_dtype) before
                                 the multiply-accumulate
  =============================  =======================================

The input slab ``x`` is handed to the kernel whole, in ``ANY`` (compiler
-chosen, HBM at size) memory space; each window row crosses HBM exactly
once per stage.  The legacy two-pass path -- XLA gather materializing
``[B, S, BUF, F]`` windows in HBM, then :func:`spmm_block_ell_staged` --
is kept for A/B benchmarking under ``ops.apply_operator(staging=
"gather")``.

Scalar prefetch is *chunked*: the descriptors (``winsegs`` or the raw
``winmap``) for at most ``smem_budget`` bytes of row-blocks are
prefetched per inner ``pallas_call``, and an outer ``lax.scan`` walks
the B-chunks (the same shape trick the legacy gather path uses for its
HBM transient).  Production-B shards therefore no longer hit the
whole-shard SMEM cliff the ROADMAP flagged; ``smem_bytes``/
``seg_smem_bytes`` size one chunk and raise a named ``ValueError`` when
even a single row-block cannot fit.

The double-buffered working set (R*K indices + R*K values + 2 window
slots + R*F accumulator) is sized to sit in the paper's ~96 KB
shared-memory budget; see ``vmem_bytes`` below, used by the §Perf sweep
and pinned by ``tests/test_kernel_spmm.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "spmm_block_ell",
    "spmm_block_ell_staged",
    "vmem_bytes",
    "smem_bytes",
    "seg_smem_bytes",
    "SMEM_BUDGET",
    "VMEM_BUDGET",
]

# Per-call scalar-memory budget for the prefetched window descriptors.
# One chunk's descriptors must fit; the outer scan covers the rest.
SMEM_BUDGET = 256 << 10
# Per-grid-step on-chip working set ceiling (real VMEM is ~16 MB; the
# paper's shared-memory budget is far tighter -- see vmem_bytes).
VMEM_BUDGET = 16 << 20


def _fma_block(inds_ref, window, vals_ref, compute_dtype, scale=None):
    """out[R, F] = sum_k vals[:, k] * window[inds[:, k]] for one stage.

    ``scale`` (a compute-dtype scalar, from the scalar-prefetched
    per-block exponents of the quantized tier) dequantizes int8/fp8
    vals inline: the multiply rides the same VREG upcast the f16 path
    already pays, so quantization costs no extra HBM stream and no
    extra FMA pass.
    """
    inds = inds_ref[0, 0].astype(jnp.int32)  # [R, K]
    vals = vals_ref[0, 0].astype(compute_dtype)  # [R, K]
    if scale is not None:
        vals = vals * scale
    window = window.astype(compute_dtype)  # [BUF, F]
    r, k = inds.shape
    f = window.shape[-1]

    def body(j, acc):
        # One {index, length} pair per row, reused across all F fused
        # slices (the paper's register-reuse step, F-wide on the VPU).
        col = inds[:, j]  # [R]
        gathered = jnp.take(window, col, axis=0)  # [R, F]
        return acc + vals[:, j][:, None] * gathered

    return jax.lax.fori_loop(
        0, k, body, jnp.zeros((r, f), compute_dtype), unroll=4
    )


def _dma_classes(buf: int) -> tuple:
    """Static power-of-two copy lengths a decomposed segment can have.

    ``ops.winmap_segments`` splits every run into power-of-two pieces,
    so the kernel can issue fixed-size copies (Pallas DMAs need static
    extents) while still moving one *run* in O(log) issues instead of
    O(len) per-row issues.
    """
    classes = []
    ln = 1
    while ln <= max(1, buf):
        classes.append(ln)
        ln *= 2
    return tuple(classes)


def _block_scale(scl_ref, i, s, compute_dtype):
    """Dequant factor ``2**exp`` of block (i, s) from the prefetched
    exponent table; ldexp so the factor is bit-exact (power of two)."""
    return jnp.ldexp(
        jnp.ones((), compute_dtype), scl_ref[i, s]
    )


def _spmm_fused_kernel(
    winmap_ref,  # [Bc, S, BUF] int32, scalar-prefetched (SMEM)
    *rest,  # [scl_ref,] inds_ref, vals_ref, x_ref, out_ref, win, sems
    compute_dtype,
    buf: int,
    quantized: bool = False,
):
    """One (row-block, stage) grid step; per-row window DMAs (A/B path).

    With ``quantized=True`` a second scalar-prefetch operand
    ``scl_ref [Bc, S]`` (int32 dequant exponents) precedes the VMEM
    refs: inds [1,1,R,K] int16, vals [1,1,R,K] (int8/fp8 when
    quantized), x [C,F] (ANY), out [1,R,F], then the window scratch and
    DMA semaphores.
    """
    if quantized:
        scl_ref, inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    else:
        scl_ref = None
        inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    i, s = pl.program_id(0), pl.program_id(1)
    n_s = pl.num_programs(1)
    step = i * n_s + s  # linear stage counter across the whole grid
    n_steps = pl.num_programs(0) * n_s

    def window_dma(which, slot, op):
        """Issue (or await) the buffer-load loop of linear stage
        ``which`` into window slot ``slot``: one async row copy per
        ``winmap`` entry, HBM -> VMEM (Listing 1 lines 15-20)."""
        bi, si = which // n_s, which % n_s

        def one_row(j, carry):
            dma = pltpu.make_async_copy(
                x_ref.at[winmap_ref[bi, si, j]],
                win.at[slot, j],
                sems.at[slot],
            )
            getattr(dma, op)()
            return carry

        jax.lax.fori_loop(0, buf, one_row, None)

    _staged_pipeline(window_dma, step, n_steps, s, out_ref)
    scale = (
        _block_scale(scl_ref, i, s, compute_dtype) if quantized else None
    )
    acc = _fma_block(
        inds_ref, win[step % 2], vals_ref, compute_dtype, scale
    )
    out_ref[...] += acc.astype(out_ref.dtype)


def _spmm_fused_kernel_coalesced(
    segs_ref,  # [Bc, S, NSEG, 3] int32 {src, dst, len} (SMEM)
    *rest,  # [scl_ref,] inds_ref, vals_ref, x_ref, out_ref, win, sems
    compute_dtype,
    nseg: int,
    classes: tuple,
    quantized: bool = False,
):
    """One (row-block, stage) grid step; run-length-coalesced DMAs.

    The buffer-load loop issues ONE strided ``make_async_copy`` per
    run-length segment: ``x[src:src+len] -> win[slot, dst:dst+len]``,
    ``len`` a power of two from the static ``classes`` (pad segments
    have ``len == 0`` and issue nothing).  Start and wait walk the same
    predicates, so semaphore counts always balance.  ``quantized``
    prepends the int32 exponent table ``scl_ref [Bc, S]`` to the refs
    (see ``_spmm_fused_kernel``).
    """
    if quantized:
        scl_ref, inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    else:
        scl_ref = None
        inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    i, s = pl.program_id(0), pl.program_id(1)
    n_s = pl.num_programs(1)
    step = i * n_s + s
    n_steps = pl.num_programs(0) * n_s

    def window_dma(which, slot, op):
        bi, si = which // n_s, which % n_s
        for ln in classes:  # static unroll: DMA extents must be static

            def one_seg(j, carry, ln=ln):
                @pl.when(segs_ref[bi, si, j, 2] == ln)
                def _copy():
                    dma = pltpu.make_async_copy(
                        x_ref.at[pl.ds(segs_ref[bi, si, j, 0], ln)],
                        win.at[slot, pl.ds(segs_ref[bi, si, j, 1], ln)],
                        sems.at[slot],
                    )
                    getattr(dma, op)()

                return carry

            jax.lax.fori_loop(0, nseg, one_seg, None)

    _staged_pipeline(window_dma, step, n_steps, s, out_ref)
    scale = (
        _block_scale(scl_ref, i, s, compute_dtype) if quantized else None
    )
    acc = _fma_block(
        inds_ref, win[step % 2], vals_ref, compute_dtype, scale
    )
    out_ref[...] += acc.astype(out_ref.dtype)


def _spmm_fused_kernel_coalesced_sorted(
    segs_ref,  # [Bc, S, NSEG, 3] int32 {src, dst, len}, class-sorted (SMEM)
    off_ref,  # [Bc, S, NCLS+1] int32 per-class slot offsets (SMEM)
    *rest,  # [scl_ref,] inds_ref, vals_ref, x_ref, out_ref, win, sems
    compute_dtype,
    classes: tuple,  # descending copy lengths, matching off_ref's axis
    quantized: bool = False,
):
    """One (row-block, stage) grid step; class-sorted coalesced DMAs.

    ``ops.sort_segments_by_class`` groups each stage's segments by copy
    length, so every static length class loops -- with *dynamic*
    ``fori_loop`` bounds from the prefetched offset table -- over exactly
    its own slots and issues unconditional fixed-extent copies.  Issue
    work is O(real segments) per window, vs the unsorted fallback's
    O(classes x NSEG) per-slot class tests (the interpret-mode 10x
    inversion).  Start and wait walk the same bounds, so semaphore
    counts always balance.  ``quantized`` appends the int32 exponent
    table ``scl_ref [Bc, S]`` as a third scalar-prefetch operand (see
    ``_spmm_fused_kernel``).
    """
    if quantized:
        scl_ref, inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    else:
        scl_ref = None
        inds_ref, vals_ref, x_ref, out_ref, win, sems = rest
    i, s = pl.program_id(0), pl.program_id(1)
    n_s = pl.num_programs(1)
    step = i * n_s + s
    n_steps = pl.num_programs(0) * n_s

    def window_dma(which, slot, op):
        bi, si = which // n_s, which % n_s
        for ci, ln in enumerate(classes):  # static unroll over classes

            def one_seg(j, carry, ln=ln):
                dma = pltpu.make_async_copy(
                    x_ref.at[pl.ds(segs_ref[bi, si, j, 0], ln)],
                    win.at[slot, pl.ds(segs_ref[bi, si, j, 1], ln)],
                    sems.at[slot],
                )
                getattr(dma, op)()
                return carry

            jax.lax.fori_loop(
                off_ref[bi, si, ci], off_ref[bi, si, ci + 1],
                one_seg, None,
            )

    _staged_pipeline(window_dma, step, n_steps, s, out_ref)
    scale = (
        _block_scale(scl_ref, i, s, compute_dtype) if quantized else None
    )
    acc = _fma_block(
        inds_ref, win[step % 2], vals_ref, compute_dtype, scale
    )
    out_ref[...] += acc.astype(out_ref.dtype)


def _staged_pipeline(window_dma, step, n_steps, s, out_ref):
    """The shared multi-stage double-buffer schedule: prologue-load the
    first window, prefetch stage ``step+1`` before computing ``step``."""

    @pl.when(step == 0)
    def _prologue():  # no stage before the first: load it synchronously
        window_dma(0, 0, "start")

    @pl.when(step + 1 < n_steps)
    def _prefetch():  # overlap stage step+1's loads with this stage's FMAs
        window_dma(step + 1, (step + 1) % 2, "start")

    window_dma(step, step % 2, "wait")

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)


def _spmm_staged_kernel(
    inds_ref, vals_ref, win_ref, out_ref, *, compute_dtype
):
    """Legacy step: windows pre-staged in HBM, delivered by BlockSpec."""
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = _fma_block(inds_ref, win_ref[0, 0], vals_ref, compute_dtype)
    out_ref[...] += acc.astype(out_ref.dtype)


def vmem_bytes(
    r: int,
    k: int,
    buf: int,
    f: int,
    store_bytes: int = 2,
    stages_buffered: int = 2,
    budget: int | None = None,
    win_bytes: int | None = None,
) -> int:
    """Per-grid-step VMEM footprint (the paper's 96 KB shared-mem budget).

    The fused path holds ``stages_buffered`` window slots (double
    buffering: stage ``s+1`` streams in while stage ``s`` computes);
    the staging memory is O(VMEM), not an O(64 MB) HBM transient.

    ``store_bytes`` sizes the value tile; ``win_bytes`` the staged
    window slots (the input-vector storage dtype).  They coincide for
    the float ladder, but the quantized tier packs int8/fp8 vals under
    f16 windows -- ``win_bytes=None`` keeps the legacy coupled sizing.

    With ``budget=`` the request is validated: a footprint above the
    budget raises a ``ValueError`` naming the dominant dimension to
    shrink, instead of letting Mosaic fail opaquely at lower time.
    """
    wb = store_bytes if win_bytes is None else win_bytes
    terms = {
        "R*K (inds, int16)": r * k * 2,
        "R*K (vals)": r * k * store_bytes,
        "BUF*F (window slots)": stages_buffered * buf * f * wb,
        "R*F (fp32 accumulator)": r * f * 4,
    }
    total = sum(terms.values())
    if budget is not None and total > budget:
        worst = max(terms, key=terms.get)  # type: ignore[arg-type]
        raise ValueError(
            f"kernel working set {total} B exceeds the {budget} B VMEM "
            f"budget (R={r}, K={k}, BUF={buf}, F={f}); the dominant "
            f"term is {worst} = {terms[worst]} B -- shrink that "
            "dimension (rows_per_block / nnz_per_stage / window / fuse)"
        )
    return total


def smem_bytes(
    b: int, s: int, buf: int, budget: int | None = None
) -> int:
    """Scalar-memory footprint of a prefetched per-row ``winmap`` chunk
    (int32), for ``b`` row-blocks.

    ``spmm_block_ell`` chunks the prefetch over row-blocks so only one
    chunk's descriptors sit in SMEM at a time; pass ``budget=`` to
    validate a chunk -- a single row-block that cannot fit raises a
    named ``ValueError`` (satellite of the ROADMAP on-TPU item).
    """
    total = b * s * buf * 4
    if budget is not None and total > budget:
        raise ValueError(
            f"winmap chunk of {b} row-block(s) needs {total} B of SMEM "
            f"(B_chunk={b} x S={s} x BUF={buf} x 4 B) but the budget is "
            f"{budget} B; the offending dimensions are S*BUF = "
            f"{s * buf} entries per row-block -- reduce the window "
            "(BUF) or stage count (S), or raise smem_budget"
        )
    return total


def seg_smem_bytes(
    b: int, s: int, nseg: int, budget: int | None = None,
    noff: int = 0,
) -> int:
    """Scalar-memory footprint of a prefetched ``winsegs`` chunk
    (int32 ``{src, dst, len}`` triples), for ``b`` row-blocks.
    ``noff`` adds the per-class offset table entries of the class-sorted
    path (``NCLS+1`` int32 per (row-block, stage))."""
    total = b * s * (nseg * 3 + noff) * 4
    if budget is not None and total > budget:
        raise ValueError(
            f"winsegs chunk of {b} row-block(s) needs {total} B of SMEM "
            f"(B_chunk={b} x S={s} x NSEG={nseg} x 12 B) but the budget "
            f"is {budget} B; the offending dimensions are S*NSEG = "
            f"{s * nseg} segments per row-block -- a more fragmented "
            "winmap (shorter runs) raises NSEG; reduce S/BUF or raise "
            "smem_budget"
        )
    return total


def _prefetch_chunk_blocks(
    b: int, per_block_bytes: int, budget: int
) -> int:
    """Largest divisor of ``b`` whose descriptor chunk fits ``budget``."""
    want = max(1, budget // max(1, per_block_bytes))
    if want >= b:
        return b
    for d in range(min(want, b), 0, -1):
        if b % d == 0:
            return d
    return 1


@functools.partial(
    jax.jit,
    static_argnames=("compute_dtype", "interpret", "smem_budget"),
)
def spmm_block_ell(
    inds,
    vals,
    winmap,
    x,
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
    winsegs=None,
    segoff=None,
    smem_budget: int | None = None,
    scales=None,
):
    """Fused multi-stage SpMM over one device's blocked-ELL shard, with
    the window staging done *inside* the kernel (paper Listing 1).

    Args:
      inds:   [B, S, R, K] int16 window-local indices.
      vals:   [B, S, R, K] storage-dtype lengths.
      winmap: [B, S, BUF] int32 device-local input column ids (per-row
              DMA path; ignored when ``winsegs`` is given).
      x:      [C, F] local input slab (storage dtype).  Stays whole in
              HBM; the kernel double-buffers each stage's BUF-row window
              into VMEM with async copies.  No ``[B, S, BUF, F]`` tensor
              is ever materialized.
      compute_dtype: FMA dtype (fp32 for the paper's mixed mode).
      interpret: force Pallas interpret mode; defaults to True off-TPU.
      winsegs: [B, S, NSEG, 3] int32 run-length segments from
              ``ops.winmap_segments``; when given, the kernel issues one
              coalesced multi-row copy per segment instead of one copy
              per ``winmap`` row (the default production path -- see
              ``ops.apply_operator(dma=...)``).
      segoff: [B, S, NCLS+1] int32 per-length-class offsets into a
              class-sorted ``winsegs`` (``ops.sort_segments_by_class``);
              when given, each class loops over exactly its own slots
              (O(segments) issue work); when omitted the kernel tests
              every slot against every class (legacy unsorted tables).
      smem_budget: per-call scalar-memory budget for the prefetched
              descriptors; the prefetch is chunked over row-blocks to
              fit (outer ``lax.scan``), so shards of any B run.
              Defaults to ``SMEM_BUDGET``.
      scales: [B, S] int32 per-block *dequantization* exponents
              (``core.precision.quantize_block_vals``); when given,
              ``vals`` is int8/fp8 and the kernel multiplies each
              block's FMA by ``2.0**scales[b, s]`` inline.  The table
              rides the scalar-prefetch path next to winmap/segoff
              (4 B per (row-block, stage) of SMEM, no HBM stream).

    Returns:
      [B, R, F] fp32 partial output band blocks.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    budget = SMEM_BUDGET if smem_budget is None else smem_budget
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x.shape[-1]
    vmem_bytes(
        r, k, buf, f, jnp.dtype(vals.dtype).itemsize,
        win_bytes=jnp.dtype(x.dtype).itemsize, budget=VMEM_BUDGET,
    )
    coalesced = winsegs is not None
    sorted_segs = coalesced and segoff is not None
    # validates too: a single over-budget row-block raises a named error
    per_block = (
        seg_smem_bytes(
            1, s, winsegs.shape[-2], budget=budget,
            noff=segoff.shape[-1] if sorted_segs else 0,
        )
        if coalesced
        else smem_bytes(1, s, buf, budget=budget)
    )
    bpc = _prefetch_chunk_blocks(b, per_block, budget)

    def one_call(ic, vc, wc, sc, oc, qc):
        qc = qc if scales is not None else None  # scan dummy -> None
        if sorted_segs:
            return _pallas_fused_coalesced_sorted(
                ic, vc, sc, oc, x, buf, compute_dtype, interpret,
                scales=qc,
            )
        if coalesced:
            return _pallas_fused_coalesced(
                ic, vc, sc, x, buf, compute_dtype, interpret, scales=qc
            )
        return _pallas_fused_per_row(
            ic, vc, wc, x, compute_dtype, interpret, scales=qc
        )

    if bpc >= b:
        return one_call(inds, vals, winmap, winsegs, segoff, scales)

    n_chunk = b // bpc

    def step(_, args):
        return None, one_call(*args)

    dummy = jnp.zeros((n_chunk, 1), jnp.int32)  # unused scan carries

    _, outs = jax.lax.scan(
        step,
        None,
        (
            inds.reshape(n_chunk, bpc, s, r, k),
            vals.reshape(n_chunk, bpc, s, r, k),
            winmap.reshape(n_chunk, bpc, s, buf),
            (
                winsegs.reshape(n_chunk, bpc, s, *winsegs.shape[2:])
                if coalesced
                else dummy
            ),
            (
                segoff.reshape(n_chunk, bpc, s, segoff.shape[-1])
                if sorted_segs
                else dummy
            ),
            (
                scales.reshape(n_chunk, bpc, s)
                if scales is not None
                else dummy
            ),
        ),
    )
    return outs.reshape(b, r, f)


def _pallas_fused_per_row(inds, vals, winmap, x, compute_dtype,
                          interpret, scales=None):
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x.shape[-1]
    kernel = functools.partial(
        _spmm_fused_kernel, compute_dtype=compute_dtype, buf=buf,
        quantized=scales is not None,
    )
    pre = (winmap.astype(jnp.int32),) + (
        (scales.astype(jnp.int32),) if scales is not None else ()
    )
    return pl.pallas_call(
        kernel,
        grid_spec=_fused_grid_spec(
            b, s, r, k, buf, f, x.dtype, num_scalar_prefetch=len(pre)
        ),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        # cross-step window prefetch orders the whole grid
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*pre, inds, vals, x)


def _pallas_fused_coalesced(inds, vals, winsegs, x, buf, compute_dtype,
                            interpret, scales=None):
    """``buf`` (the scratch window height every dst range fits in) comes
    from the caller's ``winmap.shape[-1]`` -- ``winmap_segments`` tiles
    exactly ``[0, BUF)`` with its dst ranges."""
    b, s, r, k = inds.shape
    nseg = winsegs.shape[-2]
    f = x.shape[-1]
    kernel = functools.partial(
        _spmm_fused_kernel_coalesced,
        compute_dtype=compute_dtype,
        nseg=nseg,
        classes=_dma_classes(buf),
        quantized=scales is not None,
    )
    pre = (winsegs.astype(jnp.int32),) + (
        (scales.astype(jnp.int32),) if scales is not None else ()
    )
    return pl.pallas_call(
        kernel,
        grid_spec=_fused_grid_spec(
            b, s, r, k, buf, f, x.dtype, num_scalar_prefetch=len(pre)
        ),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*pre, inds, vals, x)


def _pallas_fused_coalesced_sorted(inds, vals, winsegs, segoff, x, buf,
                                   compute_dtype, interpret, scales=None):
    """Class-sorted table + offsets: the default production path."""
    b, s, r, k = inds.shape
    f = x.shape[-1]
    classes = _dma_classes(buf)[::-1]  # descending, = segoff's axis
    if segoff.shape[-1] != len(classes) + 1:
        raise ValueError(
            f"segoff carries {segoff.shape[-1] - 1} length classes but "
            f"BUF={buf} implies {len(classes)} "
            "(sort_segments_by_class(winsegs, buf) with the same buf)"
        )
    kernel = functools.partial(
        _spmm_fused_kernel_coalesced_sorted,
        compute_dtype=compute_dtype,
        classes=classes,
        quantized=scales is not None,
    )
    pre = (winsegs.astype(jnp.int32), segoff.astype(jnp.int32)) + (
        (scales.astype(jnp.int32),) if scales is not None else ()
    )
    return pl.pallas_call(
        kernel,
        grid_spec=_fused_grid_spec(
            b, s, r, k, buf, f, x.dtype, num_scalar_prefetch=len(pre)
        ),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*pre, inds, vals, x)


def _fused_grid_spec(b, s, r, k, buf, f, x_dtype,
                     num_scalar_prefetch: int = 1):
    # index maps take the grid indices plus one trailing arg per
    # scalar-prefetch operand; *refs absorbs either arity
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=(b, s),
        in_specs=[
            pl.BlockSpec((1, 1, r, k), lambda i, j, *refs: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, k), lambda i, j, *refs: (i, j, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, r, f), lambda i, j, *refs: (i, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((2, buf, f), x_dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )


@functools.partial(
    jax.jit, static_argnames=("compute_dtype", "interpret")
)
def spmm_block_ell_staged(
    inds,
    vals,
    window,
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Legacy two-pass SpMM: consumes HBM-pre-staged windows.

    Kept for A/B benchmarking against the fused path
    (``ops.apply_operator(staging="gather")``): the caller materializes
    ``window[B, S, BUF, F]`` with an XLA gather (one extra HBM round
    trip) and BlockSpec delivers one ``[BUF, F]`` tile per grid step.

    Returns [B, R, F] fp32 partial output band blocks.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, r, k = inds.shape
    buf, f = window.shape[-2:]
    kernel = functools.partial(
        _spmm_staged_kernel, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(b, s),
        in_specs=[
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, buf, f), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, f), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(inds, vals, window)
