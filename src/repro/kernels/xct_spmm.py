"""XCT-optimized fused SpMM as a Pallas TPU kernel.

TPU re-derivation of the paper's Listing 1 (Sec. III-B), including the
*buffer-load loop* (lines 15-20): the kernel itself streams each stage's
window of input rows from HBM into on-chip memory, so no staged window
tensor ever exists in HBM.  The CUDA kernel's mechanisms map as follows:

  =============================  =======================================
  Listing 1 (CUDA)               this kernel (Pallas TPU)
  =============================  =======================================
  shared-memory 3D input buffer  VMEM scratch ``win[2, BUF, F]``
  buffer-load loop (l. 15-20)    per-row async DMAs HBM -> VMEM, driven
                                 by the scalar-prefetched ``winmap``
                                 (SMEM, ``PrefetchScalarGridSpec``)
  multi-stage buffering          second grid dimension ``s``; the output
                                 block is revisited across stages and
                                 accumulated in fp32 (TPU grids execute
                                 sequentially over revisited blocks)
  __syncthreads() double-buffer  two window slots + DMA semaphores:
                                 stage ``n+1``'s loads are issued before
                                 stage ``n``'s FMAs run (overlap)
  register reuse across FFACTOR  the fused-slice dim ``F`` is the minor
                                 (lane) dimension; one {index, len} pair
                                 drives an F-wide VPU FMA
  {uint16, half} 4-byte packing  int16 index tile + fp16/bf16 value tile
                                 (4 B/nnz in HBM); upcast in-VREG
  fp32 FMA on fp16 data          explicit astype(compute_dtype) before
                                 the multiply-accumulate
  =============================  =======================================

The input slab ``x`` is handed to the kernel whole, in ``ANY`` (compiler
-chosen, HBM at size) memory space; each window row crosses HBM exactly
once per stage.  The legacy two-pass path -- XLA gather materializing
``[B, S, BUF, F]`` windows in HBM, then :func:`spmm_block_ell_staged` --
is kept for A/B benchmarking under ``ops.apply_operator(staging=
"gather")``.

The double-buffered working set (R*K indices + R*K values + 2 window
slots + R*F accumulator) is sized to sit in the paper's ~96 KB
shared-memory budget; see ``vmem_bytes`` below, used by the §Perf sweep
and pinned by ``tests/test_kernel_spmm.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "spmm_block_ell",
    "spmm_block_ell_staged",
    "vmem_bytes",
    "smem_bytes",
]


def _fma_block(inds_ref, window, vals_ref, compute_dtype):
    """out[R, F] = sum_k vals[:, k] * window[inds[:, k]] for one stage."""
    inds = inds_ref[0, 0].astype(jnp.int32)  # [R, K]
    vals = vals_ref[0, 0].astype(compute_dtype)  # [R, K]
    window = window.astype(compute_dtype)  # [BUF, F]
    r, k = inds.shape
    f = window.shape[-1]

    def body(j, acc):
        # One {index, length} pair per row, reused across all F fused
        # slices (the paper's register-reuse step, F-wide on the VPU).
        col = inds[:, j]  # [R]
        gathered = jnp.take(window, col, axis=0)  # [R, F]
        return acc + vals[:, j][:, None] * gathered

    return jax.lax.fori_loop(
        0, k, body, jnp.zeros((r, f), compute_dtype), unroll=4
    )


def _spmm_fused_kernel(
    winmap_ref,  # [B, S, BUF] int32, scalar-prefetched (SMEM)
    inds_ref,  # [1, 1, R, K] int16 block (VMEM)
    vals_ref,  # [1, 1, R, K] storage-dtype block (VMEM)
    x_ref,  # [C, F] whole local slab (ANY -> HBM at size)
    out_ref,  # [1, R, F] fp32 block, revisited across stages
    win,  # VMEM scratch [2, BUF, F]: double-buffered window slots
    sems,  # DMA semaphores [2]
    *,
    compute_dtype,
    buf: int,
):
    """One (row-block, stage) grid step with in-kernel window staging."""
    i, s = pl.program_id(0), pl.program_id(1)
    n_s = pl.num_programs(1)
    step = i * n_s + s  # linear stage counter across the whole grid
    n_steps = pl.num_programs(0) * n_s

    def window_dma(which, slot, op):
        """Issue (or await) the buffer-load loop of linear stage
        ``which`` into window slot ``slot``: one async row copy per
        ``winmap`` entry, HBM -> VMEM (Listing 1 lines 15-20)."""
        bi, si = which // n_s, which % n_s

        def one_row(j, carry):
            dma = pltpu.make_async_copy(
                x_ref.at[winmap_ref[bi, si, j]],
                win.at[slot, j],
                sems.at[slot],
            )
            getattr(dma, op)()
            return carry

        jax.lax.fori_loop(0, buf, one_row, None)

    @pl.when(step == 0)
    def _prologue():  # no stage before the first: load it synchronously
        window_dma(0, 0, "start")

    @pl.when(step + 1 < n_steps)
    def _prefetch():  # overlap stage step+1's loads with this stage's FMAs
        window_dma(step + 1, (step + 1) % 2, "start")

    window_dma(step, step % 2, "wait")

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = _fma_block(inds_ref, win[step % 2], vals_ref, compute_dtype)
    out_ref[...] += acc.astype(out_ref.dtype)


def _spmm_staged_kernel(
    inds_ref, vals_ref, win_ref, out_ref, *, compute_dtype
):
    """Legacy step: windows pre-staged in HBM, delivered by BlockSpec."""
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = _fma_block(inds_ref, win_ref[0, 0], vals_ref, compute_dtype)
    out_ref[...] += acc.astype(out_ref.dtype)


def vmem_bytes(
    r: int,
    k: int,
    buf: int,
    f: int,
    store_bytes: int = 2,
    stages_buffered: int = 2,
) -> int:
    """Per-grid-step VMEM footprint (the paper's 96 KB shared-mem budget).

    The fused path holds ``stages_buffered`` window slots (double
    buffering: stage ``s+1`` streams in while stage ``s`` computes);
    the staging memory is O(VMEM), not an O(64 MB) HBM transient.
    """
    return (
        r * k * 2  # inds (int16)
        + r * k * store_bytes  # vals
        + stages_buffered * buf * f * store_bytes  # window slots
        + r * f * 4  # fp32 accumulator / output block
    )


def smem_bytes(b: int, s: int, buf: int) -> int:
    """Scalar-memory footprint of the prefetched ``winmap`` (int32).

    The fused kernel prefetches the *whole* ``[B, S, BUF]`` winmap, so
    this grows with the shard's block count B -- unlike ``vmem_bytes``,
    which is per-grid-step.  Tier-1/bench shards sit far inside scalar
    memory (pinned by ``tests/test_kernel_spmm.py``); production-B
    shards need the winmap prefetch chunked over row-blocks before the
    kernel is run on real hardware (ROADMAP: on-TPU validation).
    """
    return b * s * buf * 4


@functools.partial(
    jax.jit, static_argnames=("compute_dtype", "interpret")
)
def spmm_block_ell(
    inds,
    vals,
    winmap,
    x,
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Fused multi-stage SpMM over one device's blocked-ELL shard, with
    the window staging done *inside* the kernel (paper Listing 1).

    Args:
      inds:   [B, S, R, K] int16 window-local indices.
      vals:   [B, S, R, K] storage-dtype lengths.
      winmap: [B, S, BUF] int32 device-local input column ids; scalar-
              prefetched to SMEM so the kernel can compute DMA source
              addresses before each stage runs.
      x:      [C, F] local input slab (storage dtype).  Stays whole in
              HBM; the kernel double-buffers each stage's BUF-row window
              into VMEM with async copies.  No ``[B, S, BUF, F]`` tensor
              is ever materialized.
      compute_dtype: FMA dtype (fp32 for the paper's mixed mode).
      interpret: force Pallas interpret mode; defaults to True off-TPU.

    Returns:
      [B, R, F] fp32 partial output band blocks.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, r, k = inds.shape
    buf = winmap.shape[-1]
    f = x.shape[-1]
    kernel = functools.partial(
        _spmm_fused_kernel, compute_dtype=compute_dtype, buf=buf
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, s),
        in_specs=[
            pl.BlockSpec((1, 1, r, k), lambda i, j, wm: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, k), lambda i, j, wm: (i, j, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, r, f), lambda i, j, wm: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, buf, f), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        # cross-step window prefetch orders the whole grid
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(winmap.astype(jnp.int32), inds, vals, x)


@functools.partial(
    jax.jit, static_argnames=("compute_dtype", "interpret")
)
def spmm_block_ell_staged(
    inds,
    vals,
    window,
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Legacy two-pass SpMM: consumes HBM-pre-staged windows.

    Kept for A/B benchmarking against the fused path
    (``ops.apply_operator(staging="gather")``): the caller materializes
    ``window[B, S, BUF, F]`` with an XLA gather (one extra HBM round
    trip) and BlockSpec delivers one ``[BUF, F]`` tile per grid step.

    Returns [B, R, F] fp32 partial output band blocks.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, r, k = inds.shape
    buf, f = window.shape[-2:]
    kernel = functools.partial(
        _spmm_staged_kernel, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(b, s),
        in_specs=[
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, buf, f), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, f), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(inds, vals, window)
