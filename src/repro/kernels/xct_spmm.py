"""XCT-optimized fused SpMM as a Pallas TPU kernel.

TPU re-derivation of the paper's Listing 1 (Sec. III-B).  The CUDA kernel's
mechanisms map as follows:

  shared-memory 3D input buffer  ->  VMEM window tile [BUF, F] delivered by
                                     BlockSpec (one per (row-block, stage))
  multi-stage buffering          ->  second grid dimension ``s``; the output
                                     block is revisited across stages and
                                     accumulated in fp32 (TPU grids execute
                                     sequentially over revisited blocks)
  register reuse across FFACTOR  ->  the fused-slice dim ``F`` is the minor
                                     (lane) dimension; one {index, len} pair
                                     drives an F-wide VPU FMA
  {uint16, half} 4-byte packing  ->  int16 index tile + fp16/bf16 value tile
                                     (4 B/nnz in HBM); upcast in-VREG
  fp32 FMA on fp16 data          ->  explicit astype(compute_dtype) before
                                     the multiply-accumulate

The kernel's working set per grid step (R*K indices + R*K values + BUF*F
window + R*F accumulator) is sized to sit comfortably in VMEM; see
``vmem_bytes`` below, used by the §Perf sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_block_ell", "vmem_bytes"]


def _spmm_kernel(inds_ref, vals_ref, win_ref, out_ref, *, compute_dtype):
    """One (row-block, stage) step: out[R, F] += sum_k vals[:,k] * win[inds]."""
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    inds = inds_ref[0, 0].astype(jnp.int32)  # [R, K]
    vals = vals_ref[0, 0].astype(compute_dtype)  # [R, K]
    window = win_ref[0, 0].astype(compute_dtype)  # [BUF, F]
    r, k = inds.shape
    f = window.shape[-1]

    def body(j, acc):
        # One {index, length} pair per row, reused across all F fused
        # slices (the paper's register-reuse step, F-wide on the VPU).
        col = inds[:, j]  # [R]
        gathered = jnp.take(window, col, axis=0)  # [R, F]
        return acc + vals[:, j][:, None] * gathered

    acc = jax.lax.fori_loop(
        0, k, body, jnp.zeros((r, f), compute_dtype), unroll=4
    )
    out_ref[...] += acc.astype(out_ref.dtype)


def vmem_bytes(r: int, k: int, buf: int, f: int, store_bytes: int = 2) -> int:
    """Per-grid-step VMEM footprint (the paper's 96 KB shared-mem budget)."""
    return (
        r * k * 2  # inds (int16)
        + r * k * store_bytes  # vals
        + buf * f * store_bytes  # window
        + r * f * 4  # fp32 accumulator / output block
    )


@functools.partial(
    jax.jit, static_argnames=("compute_dtype", "interpret")
)
def spmm_block_ell(
    inds,
    vals,
    window,
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Fused multi-stage SpMM over one device's blocked-ELL shard.

    Args:
      inds:   [B, S, R, K] int16 window-local indices.
      vals:   [B, S, R, K] storage-dtype lengths.
      window: [B, S, BUF, F] pre-staged input windows (the XLA gather that
              plays the role of Listing 1's buffer-load loop, lines 15-20).
      compute_dtype: FMA dtype (fp32 for the paper's mixed mode).
      interpret: force Pallas interpret mode; defaults to True off-TPU.

    Returns:
      [B, R, F] fp32 partial output band blocks.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, r, k = inds.shape
    buf, f = window.shape[-2:]
    grid = (b, s)
    kernel = functools.partial(_spmm_kernel, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, buf, f), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, f), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, f), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(inds, vals, window)
