"""Sharded, atomic, mesh-agnostic checkpointing.

Layout per step:

  <dir>/step_<n>.tmp/            (written first)
      manifest.json              pytree structure, global shapes, dtypes
      shard_<i>.npz              flat-leaf arrays (numpy)
  <dir>/step_<n>/                (atomic rename on completion)

Properties required at scale:

  * atomic: a crash mid-write never corrupts the latest checkpoint
    (tmp + rename; readers only ever see complete directories);
  * mesh-agnostic: leaves are stored as *global* numpy arrays plus the
    manifest, so restore can re-shard onto any mesh/topology (elastic
    restart after losing nodes -- dist/fault.py::remesh);
  * resumable solvers: arbitrary pytrees (CG state, optimizer state,
    data-pipeline step counters) round-trip, not just params.

On a real multi-host fleet each host writes only its addressable shards;
here (single host) the global array is materialized directly.  The
interface (save/restore/latest_step) is host-count independent.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {}
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": step, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
        arrays[f"leaf_{i}"] = arr
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays/SDS).

    ``shardings``: optional pytree of NamedShardings -- re-sharding onto a
    different mesh than the one that saved (elastic restart).
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        manifest["n_leaves"], len(leaves_like),
    )
    out = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        want = tuple(np.shape(ref))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {i}: checkpoint {arr.shape} vs expected {want}"
            )
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree


class CheckpointManager:
    """Every-K-steps + on-demand checkpointing with restore-or-init."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree) -> bool:
        if self.every and step % self.every == 0:
            save(self.directory, step, tree, keep=self.keep)
            return True
        return False

    def restore_or_init(self, init_fn, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return init_fn(), 0
        like = jax.eval_shape(init_fn)
        return (
            restore(self.directory, step, like, shardings=shardings),
            step,
        )
