"""Checkpointing with atomic publish and elastic restore."""
