"""XCT reconstruction driver (the paper's workload).

  PYTHONPATH=src python -m repro.launch.recon --n 64 --angles 48 \
      --slices 8 --iters 20 --precision mixed --comm hier

Out-of-core streaming (``repro.stream``): simulate the sinogram straight
into an on-disk slab store, then drain it through the solver under a
byte budget -- the volume never materializes in host RAM:

  PYTHONPATH=src python -m repro.launch.recon --n 64 --slices 32 \
      --stream --mem-budget 64
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from ..core.geometry import XCTGeometry, build_system_matrix
from ..core.partition import PartitionConfig, build_plan, default_socket
from ..core.recon import ReconConfig, Reconstructor
from ..data.phantom import phantom_slices, simulate_measurements
from ..dist import MODES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--angles", type=int, default=48)
    ap.add_argument("--slices", type=int, default=8)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--p-data", type=int, default=1)
    ap.add_argument("--fuse", type=int, default=4)
    ap.add_argument("--precision", default="mixed")
    ap.add_argument("--comm", default="hier", choices=MODES)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--stream", action="store_true",
        help="out-of-core slab streaming through repro.stream",
    )
    ap.add_argument(
        "--mem-budget", type=float, default=256.0,
        help="MiB budget for --stream slab sizing (operator + slabs)",
    )
    ap.add_argument(
        "--workdir", default=None,
        help="store + resume-manifest dir for --stream (default: temp)",
    )
    ap.add_argument(
        "--dma", default="coalesced", choices=("coalesced", "per_row"),
        help="window-DMA issue mode of the fused kernel (A/B)",
    )
    ap.add_argument(
        "--device-upload", default="overlap",
        choices=("overlap", "sync"),
        help="--stream: double-buffer the host->device slab upload "
             "in the prefetch thread (overlap) or keep it on the "
             "critical path (sync)",
    )
    ap.add_argument(
        "--tune-dir", default=None,
        help="directory of repro.tune passports; this machine's "
             "passport (by hardware fingerprint) fills every knob the "
             "command line left at its default",
    )
    ap.add_argument(
        "--max-retries", type=int, default=2,
        help="--stream: transient-failure retries per slab before "
             "quarantine (resil.RetryPolicy; total tries = retries + 1)",
    )
    ap.add_argument(
        "--fail-fast", action="store_true",
        help="--stream: re-raise the first slab failure instead of "
             "retrying / quarantining (debugging)",
    )
    ap.add_argument(
        "--trace", default=None, metavar="OUT.json",
        help="record repro.obs spans and write a Chrome trace-event "
             "JSON (load it at ui.perfetto.dev); with --stream also "
             "prints the modeled-vs-measured drift report",
    )
    args = ap.parse_args(argv)

    if args.trace:
        from ..obs import trace as obs_trace

        obs_trace.enable()

    # Passport knobs apply ONLY where the flag still holds its parser
    # default: an explicit command-line choice always beats the tuner.
    tuned: dict = {}
    if args.tune_dir:
        from ..tune.passport import resolve_passport

        pp = resolve_passport(args.tune_dir)
        if pp is not None:
            tuned = dict(pp.knobs)
            for flag in ("fuse", "precision", "comm", "dma"):
                knob = {"comm": "comm_mode"}.get(flag, flag)
                if knob in tuned and \
                        getattr(args, flag) == ap.get_default(flag):
                    setattr(args, flag, tuned[knob])
            print(f"tuning passport {pp.fingerprint} applied "
                  f"({args.tune_dir})")

    geo = XCTGeometry(n=args.n, n_angles=args.angles)
    print(f"building system matrix ({geo.n_rays} rays x {geo.n_vox} vox)")
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(
            n_data=args.p_data,
            tile=tuned.get("tile", 8),
            rows_per_block=tuned.get("rows_per_block", 32),
            nnz_per_stage=tuned.get("nnz_per_stage", 32),
            socket=default_socket(args.p_data, args.p_data),
            slot_order=tuned.get("slot_order", "runs"),
        ),
        a=a,
    )

    import jax

    n_dev = len(jax.devices())
    if args.p_data > 1 and n_dev >= args.p_data:
        mesh = jax.make_mesh(
            (n_dev // args.p_data, args.p_data), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    else:
        mesh = None
    # the wire knob only exists on the hier-sparse ladder; drop it if a
    # command-line --comm override moved off the mode the passport tuned
    wire = tuned.get("wire", "native") if args.comm == "hier-sparse" \
        else "native"
    rec = Reconstructor(
        plan, mesh=mesh,
        cfg=ReconConfig(
            precision=args.precision, comm_mode=args.comm,
            fuse=args.fuse, dma=args.dma, wire=wire,
        ),
    )

    if args.stream:
        return _run_streaming(args, geo, a, rec)

    x_true = phantom_slices(args.n, args.slices, seed=args.seed)
    sino = simulate_measurements(a, x_true, noise=args.noise,
                                 seed=args.seed)
    t0 = time.time()
    x, res = rec.reconstruct(sino, iters=args.iters)
    dt = time.time() - t0
    rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
        x_true, axis=0
    )
    print(
        f"{args.iters} CG iters on {args.slices} slices in {dt:.1f}s | "
        f"rel err mean {rel.mean():.4f} | residual "
        f"{res[0,0]:.3e} -> {res[-1,0]:.3e}"
    )
    _finish_trace(args, rec)
    return x, res


def _finish_trace(args, rec):
    """--trace epilogue: write the Perfetto JSON + print drift."""
    if not args.trace:
        return
    from ..obs import drift, export
    from ..obs import trace as obs_trace

    tracer = obs_trace.get_tracer()
    export.write_chrome_trace(args.trace, tracer)
    print(f"trace written to {args.trace} (load at ui.perfetto.dev)")
    try:
        report = drift.drift_report(
            tracer, rec=rec, iters=args.iters, n_slices=args.slices,
        )
        print(report.render())
    except ValueError as e:  # e.g. odd slice counts -- trace still lands
        print(f"drift report unavailable: {e}")


def _run_streaming(args, geo, a, rec):
    """Simulate -> store -> budgeted slab drain -> slab-wise QA."""
    from ..resil import RetryPolicy
    from ..stream import SlabStore, reconstruct_streaming, simulate_to_store

    workdir = args.workdir or tempfile.mkdtemp(prefix="xct_stream_")
    granule = rec.n_batch * rec.cfg.fuse
    sino_store = SlabStore.create(
        os.path.join(workdir, "sino"), geo.n_rays, args.slices, granule
    )
    print(
        f"simulating {args.slices} slices into {sino_store.directory} "
        f"({granule}-slice writer slabs)"
    )
    simulate_to_store(
        a, args.n, sino_store, noise=args.noise, seed=args.seed
    )
    budget = int(args.mem_budget * 2**20)
    t0 = time.time()
    result = reconstruct_streaming(
        rec, sino_store, os.path.join(workdir, "vol"),
        iters=args.iters, mem_budget=budget,
        ckpt_dir=os.path.join(workdir, "ckpt"),
        device_upload=args.device_upload,
        retry=RetryPolicy(max_attempts=max(args.max_retries, 0) + 1),
        fail_fast=args.fail_fast,
    )
    dt = time.time() - t0
    # slab-wise QA: the full volume never lives in host memory.
    # Quarantined slabs have no shard on disk -- skip them.
    failed = set(result.failed_slabs)
    errs = []
    for j0, j1 in result.volume.slabs():
        if j0 in failed:
            continue
        x_true = phantom_slices(
            args.n, args.slices, seed=args.seed, start=j0, stop=j1
        )
        x = result.volume.read(j0, j1)
        errs.append(
            np.linalg.norm(x - x_true, axis=0)
            / np.linalg.norm(x_true, axis=0)
        )
    rel = (
        np.concatenate(errs) if errs else np.asarray([np.nan])
    )
    split = ""
    if result.solved:
        split = (
            f" | per-slab load/upload/solve "
            f"{np.mean(result.load_s) * 1e3:.0f}/"
            f"{np.mean(result.upload_s) * 1e3:.0f}/"
            f"{np.mean(result.solve_s) * 1e3:.0f} ms"
            + (" (upload hidden)" if result.upload_overlapped else "")
        )
    print(
        f"streamed {args.slices} slices in "
        f"{len(result.solved)} slab(s) of {result.y_slab} "
        f"(budget {args.mem_budget:.0f} MiB, skipped "
        f"{len(result.skipped)} via resume manifest) in {dt:.1f}s | "
        f"{args.slices / dt:.1f} slices/s | rel err mean "
        f"{rel.mean():.4f}" + split
    )
    if result.retries:
        print(f"absorbed {result.retries} transient retr"
              f"{'y' if result.retries == 1 else 'ies'}")
    _finish_trace(args, rec)
    if result.failed_slabs:
        print(
            f"PARTIAL: quarantined slab(s) at j0={result.failed_slabs} "
            f"-- resume with the same --workdir to re-attempt"
        )
        raise SystemExit(3)
    return result, rel


if __name__ == "__main__":
    main()
