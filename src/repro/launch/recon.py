"""XCT reconstruction driver (the paper's workload).

  PYTHONPATH=src python -m repro.launch.recon --n 64 --angles 48 \
      --slices 8 --iters 20 --precision mixed --comm hier
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.geometry import XCTGeometry, build_system_matrix
from ..core.partition import PartitionConfig, build_plan
from ..core.recon import ReconConfig, Reconstructor
from ..data.phantom import phantom_slices, simulate_measurements
from ..dist import MODES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--angles", type=int, default=48)
    ap.add_argument("--slices", type=int, default=8)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--p-data", type=int, default=1)
    ap.add_argument("--fuse", type=int, default=4)
    ap.add_argument("--precision", default="mixed")
    ap.add_argument("--comm", default="hier", choices=MODES)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    geo = XCTGeometry(n=args.n, n_angles=args.angles)
    print(f"building system matrix ({geo.n_rays} rays x {geo.n_vox} vox)")
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(
            n_data=args.p_data, tile=8,
            rows_per_block=32, nnz_per_stage=32,
        ),
        a=a,
    )
    x_true = phantom_slices(args.n, args.slices, seed=args.seed)
    sino = simulate_measurements(a, x_true, noise=args.noise,
                                 seed=args.seed)

    import jax

    n_dev = len(jax.devices())
    if args.p_data > 1 and n_dev >= args.p_data:
        mesh = jax.make_mesh(
            (n_dev // args.p_data, args.p_data), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    else:
        mesh = None
    rec = Reconstructor(
        plan, mesh=mesh,
        cfg=ReconConfig(
            precision=args.precision, comm_mode=args.comm,
            fuse=args.fuse,
        ),
    )
    t0 = time.time()
    x, res = rec.reconstruct(sino, iters=args.iters)
    dt = time.time() - t0
    rel = np.linalg.norm(x - x_true, axis=0) / np.linalg.norm(
        x_true, axis=0
    )
    print(
        f"{args.iters} CG iters on {args.slices} slices in {dt:.1f}s | "
        f"rel err mean {rel.mean():.4f} | residual "
        f"{res[0,0]:.3e} -> {res[-1,0]:.3e}"
    )
    return x, res


if __name__ == "__main__":
    main()
