"""XCT §Perf sweep: comm ladder x fusing factor at Brain/Charcoal scale.

Iterates the paper's own levers on the paper's own workload using the
slot-exact cost model (launch/dryrun.xct_analytic) -- no compile needed,
so the full design space is swept: communication mode
(direct / rs / hier / sparse / hier-sparse) x fusing factor F x
precision.

  PYTHONPATH=src python -m repro.launch.xct_perf

Wire volumes are not computed here: every byte count flows from
``dist.CommPlan``'s per-link-class volume model (see docs/dist_api.md
for the formulas), with the sparse-mode table capacities supplied by
``core.partition.exchange_volume_params``.  ``sweep_topology`` builds
the meshless production ladder (16-wide minor ICI "socket", 16-wide
major ICI "node", DCI across pods of 256) that
``launch.mesh.make_production_mesh`` realizes with devices attached.

Example -- per-device wire bytes of one fused reduction at xct-brain
scale (P_d = 512 across two pods), per link class:

>>> from repro.configs.xct_datasets import DATASETS
>>> from repro.core.geometry import XCTGeometry
>>> from repro.core.partition import PartitionConfig, estimate_plan
>>> ds = DATASETS["xct-brain"]
>>> plan = estimate_plan(
...     XCTGeometry(n=ds.n, n_angles=ds.k),
...     PartitionConfig(n_data=512, tile=32, rows_per_block=64,
...                     nnz_per_stage=64),
... )
>>> topo = sweep_topology(512)
>>> print(topo.describe())
Topology over 512 devices
  socket: axis 'model' x16 (ici)
    node: axis 'data' x16 (ici)
  global: axis 'pod' x2 (dci)
>>> direct = comm_volume(plan, "direct", fuse=16, comm_bytes=2, topo=topo)
>>> hier = comm_volume(plan, "hier", fuse=16, comm_bytes=2, topo=topo)
>>> hs = comm_volume(plan, "hier-sparse", fuse=16, comm_bytes=2,
...                  topo=topo)
>>> round(direct["dci"] / 2**30, 2)  # full dense partial crosses DCI
5.31
>>> round(hier["dci"] / 2**30, 4)  # ladder: 1/(socket*node) crosses
0.0207
>>> hs["dci"] < direct["dci"]  # socket dedup beats dense over DCI
True
"""
from __future__ import annotations

import json

from ..configs.xct_datasets import DATASETS
from ..core.geometry import XCTGeometry
from ..core.partition import (
    PartitionConfig,
    default_socket,
    estimate_plan,
    exchange_volume_params,
)
from ..dist import MODES, Topology
from ..kernels.traffic import (
    dma_issue_seconds,
    op_segments_per_stage,
    spmm_traffic,
)
from .hlo_analysis import HW

__all__ = ["comm_volume", "sweep_topology", "sweep"]


def sweep_topology(p_data: int, fast: int = 16, pod: int = 256) -> Topology:
    """Meshless production ladder for ``p_data`` in-slice devices.

    Mirrors ``launch.mesh.make_production_mesh``: a ``fast``-wide minor
    ICI socket, a major ICI node level filling the pod, and a DCI level
    across pods when ``p_data`` spills past one pod.
    """
    f = min(fast, p_data)
    mid = max(1, min(p_data // f, pod // f))
    rest = p_data // (f * mid)
    if f * mid * rest != p_data:
        raise ValueError(
            f"p_data={p_data} does not factor into the production "
            f"ladder (fast={fast}, pod={pod}); got {f}x{mid}x{rest}"
        )
    sizes = [("model", f, "ici")]
    if mid > 1:
        sizes.append(("data", mid, "ici"))
    if rest > 1:
        sizes.append(("pod", rest, "dci"))
    return Topology.from_sizes(sizes)


def comm_volume(plan, mode: str, fuse: int, comm_bytes: int,
                topo: Topology, wire: str = "native") -> dict:
    """Per-device wire bytes per reduction, by link class, from CommPlan.

    Sums the proj and back operators' per-link volumes under ``topo``'s
    ladder; the table capacities for the sparse modes come from
    ``core.partition.exchange_volume_params`` (exact when the plan holds
    real shards, analytic for ``estimate_plan`` abstractions).
    ``wire="q8"`` (hier-sparse only) prices the int8-compressed slow-axis
    hop of ``dist.collectives.sparse_exchange``.
    """
    out = {"ici": 0.0, "dci": 0.0}
    for op in (plan.proj, plan.back):
        dense = float(op.n_rows_pad) * fuse * comm_bytes
        # the dense modes ignore the table capacities -- skip building
        # the (possibly exact, O(P^2 V)) exchange tables for them
        params = (
            exchange_volume_params(op, topo)
            if mode in ("sparse", "hier-sparse") else {}
        )
        cp = topo.plan(mode, wire=wire, comm_bytes=comm_bytes, **params)
        for link, b in cp.wire_bytes_by_link(dense).items():
            out[link] = out.get(link, 0.0) + b
    return out


def sweep(dataset="xct-brain", p_data=512, iters=30, staging="fused",
          dma="coalesced", precision="mixed", wire="native"):
    """Full mode x fuse sweep of the analytic cost model.

    ``staging`` selects the SpMM memory-traffic model: the default
    in-kernel staging moves each window row over HBM once; the legacy
    ``"gather"`` baseline pays the extra staged-window round trip
    (``kernels.traffic.spmm_traffic`` is the shared formula).  ``dma``
    selects the window-DMA issue model: the default run-length
    coalescing issues O(NSEG) copies per stage, the ``"per_row"``
    baseline O(BUF) -- the memory term prices both as
    ``issues x per_copy_overhead + bytes / bw``
    (``kernels.traffic.dma_issue_seconds``), so the sweep shows the
    issue-overhead win at production scale.  ``precision`` names the
    policy whose storage/vals/comm widths price the traffic (the
    quantized ``"q8"`` tier shrinks the dominant operator stream);
    ``wire="q8"`` additionally compresses the hier-sparse slow hop
    (skipped for modes without one).
    """
    from ..core.precision import get_policy

    ds = DATASETS[dataset]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    topo = sweep_topology(p_data)
    pcfg = PartitionConfig(
        n_data=p_data, tile=32, rows_per_block=64, nnz_per_stage=64,
        socket=default_socket(p_data, topo.levels[0].size),
    )
    plan = estimate_plan(geo, pcfg)
    pol = get_policy(precision)
    rows = []
    nnz_total = geo.n_rays * 1.195 * ds.n
    for mode in MODES:
        mode_wire = wire if mode == "hier-sparse" else "native"
        for fuse in (1, 4, 16, 64):
            sb = pol.storage_bytes  # mixed default: f16 storage + wire
            flops = 0.0
            hbm = 0.0
            issues = 0.0
            for op in (plan.proj, plan.back):
                _, b, s, r, k = op.inds.shape
                t = spmm_traffic(
                    b, s, r, k, op.winmap.shape[-1], fuse,
                    storage_bytes=sb, vals_bytes=pol.vals_bytes,
                    staging=staging, dma=dma,
                    segments_per_stage=op_segments_per_stage(op),
                )
                flops += iters * t["flops"]
                hbm += iters * t["hbm_bytes"]
                issues += iters * t["dma_issues"]
            cv = comm_volume(
                plan, mode, fuse, pol.comm_bytes, topo, wire=mode_wire
            )
            t_comp = flops / HW.peak_flops
            t_mem = dma_issue_seconds(issues, hbm, HW.hbm_bw)
            t_coll = iters * (
                cv["ici"] / HW.ici_bw + cv["dci"] / HW.dci_bw
            )
            useful = 4.0 * nnz_total * fuse * iters / p_data
            t_step = max(t_comp, t_mem, t_coll)
            rows.append({
                "dataset": dataset, "mode": mode, "fuse": fuse,
                "t_compute": t_comp, "t_memory": t_mem,
                "t_collective": t_coll, "dma_issues": issues,
                "dominant": max(
                    (("compute", t_comp), ("memory", t_mem),
                     ("collective", t_coll)), key=lambda kv: kv[1],
                )[0],
                "t_per_slice_ms": 1e3 * t_step / fuse,
                "roofline_fraction": (
                    useful / HW.peak_flops
                ) / t_step,
            })
    return rows


def main():
    rows = sweep()
    with open("results/xct_perf_sweep.json", "w") as f:
        json.dump(rows, f, indent=1)
    hdr = (f"{'mode':12s} {'F':>3s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':>10s} {'ms/slice':>9s} {'frac':>6s}")
    print(hdr)
    for r in rows:
        print(
            f"{r['mode']:12s} {r['fuse']:3d} {r['t_compute']:8.3f} "
            f"{r['t_memory']:8.3f} {r['t_collective']:8.3f} "
            f"{r['dominant']:>10s} {r['t_per_slice_ms']:9.2f} "
            f"{r['roofline_fraction']:6.3f}"
        )


if __name__ == "__main__":
    main()
