"""XCT §Perf sweep: comm ladder x fusing factor at Brain/Charcoal scale.

Iterates the paper's own levers on the paper's own workload using the
slot-exact cost model (launch/dryrun.xct_analytic) -- no compile needed,
so the full design space is swept: communication mode
(direct / rs / hier / sparse) x fusing factor F x precision.

  PYTHONPATH=src python -m repro.launch.xct_perf
"""
from __future__ import annotations

import json

from ..configs.xct_datasets import DATASETS
from ..core.geometry import XCTGeometry
from ..core.partition import PartitionConfig, estimate_plan
from ..core.recon import ReconConfig
from .hlo_analysis import HW


def comm_volume(plan, mode: str, fuse: int, comm_bytes: int, p_data: int,
                fast: int = 16):
    """Per-device wire bytes per reduction, by mode and link class."""
    out = {"ici": 0.0, "dci": 0.0}
    for op in (plan.proj, plan.back):
        dense = float(op.n_rows_pad) * fuse * comm_bytes
        if mode == "direct":
            # all-reduce semantics: full dense partial, all links carry it
            out["ici"] += 2 * dense
            out["dci"] += 2 * dense / 256.0
        elif mode == "rs":
            out["ici"] += dense
            out["dci"] += dense / 256.0
        elif mode == "hier":
            out["ici"] += dense
            out["dci"] += dense / 256.0 / fast  # local reduction first
        elif mode == "sparse":
            v = getattr(op, "est_v", 8)
            wire = float(p_data) * v * fuse * comm_bytes
            out["ici"] += wire
            out["dci"] += wire / 256.0 / fast
    return out


def sweep(dataset="xct-brain", p_data=512, iters=30):
    ds = DATASETS[dataset]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    pcfg = PartitionConfig(
        n_data=p_data, tile=32, rows_per_block=64, nnz_per_stage=64
    )
    plan = estimate_plan(geo, pcfg)
    rows = []
    nnz_total = geo.n_rays * 1.195 * ds.n
    for mode in ("direct", "rs", "hier", "sparse"):
        for fuse in (1, 4, 16, 64):
            sb = 2  # mixed: f16/bf16 storage + wire
            flops = 0.0
            hbm = 0.0
            for op in (plan.proj, plan.back):
                _, b, s, r, k = op.inds.shape
                buf = op.winmap.shape[-1]
                slots = float(b) * s * r * k
                flops += iters * 2.0 * slots * fuse
                hbm += iters * (
                    slots * (2 + sb)
                    + float(b) * s * buf * (4 + 2 * sb * fuse)
                    + float(b) * r * fuse * 4 * 2
                )
            cv = comm_volume(plan, mode, fuse, sb, p_data)
            t_comp = flops / HW.peak_flops
            t_mem = hbm / HW.hbm_bw
            t_coll = iters * (
                cv["ici"] / HW.ici_bw + cv["dci"] / HW.dci_bw
            )
            useful = 4.0 * nnz_total * fuse * iters / p_data
            t_step = max(t_comp, t_mem, t_coll)
            rows.append({
                "dataset": dataset, "mode": mode, "fuse": fuse,
                "t_compute": t_comp, "t_memory": t_mem,
                "t_collective": t_coll,
                "dominant": max(
                    (("compute", t_comp), ("memory", t_mem),
                     ("collective", t_coll)), key=lambda kv: kv[1],
                )[0],
                "t_per_slice_ms": 1e3 * t_step / fuse,
                "roofline_fraction": (
                    useful / HW.peak_flops
                ) / t_step,
            })
    return rows


def main():
    rows = sweep()
    with open("results/xct_perf_sweep.json", "w") as f:
        json.dump(rows, f, indent=1)
    hdr = (f"{'mode':8s} {'F':>3s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':>10s} {'ms/slice':>9s} {'frac':>6s}")
    print(hdr)
    for r in rows:
        print(
            f"{r['mode']:8s} {r['fuse']:3d} {r['t_compute']:8.3f} "
            f"{r['t_memory']:8.3f} {r['t_collective']:8.3f} "
            f"{r['dominant']:>10s} {r['t_per_slice_ms']:9.2f} "
            f"{r['roofline_fraction']:6.3f}"
        )


if __name__ == "__main__":
    main()
