"""Compiled-HLO analysis: collective bytes, memory, roofline terms.

``cost_analysis``/``memory_analysis`` give FLOPs and HBM traffic of the
per-device SPMD module; collective traffic is not in cost_analysis, so we
parse the compiled HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
classifying each op by the slowest link its replica groups cross
(intra-pod ICI vs inter-pod DCI for the (2,16,16) production mesh).

Hardware model (TPU v5e-class, per chip):
  197 TFLOP/s bf16 | 819 GB/s HBM | ~50 GB/s/link ICI | DCI modeled at
  1/4 ICI (12.5 GB/s/chip; assumption recorded in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_collectives", "roofline", "HW"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9
    ici_bw: float = 50e9  # per link
    dci_bw: float = 12.5e9  # per chip across pods (assumption)


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[\d,]*\][^ ]*(?:,\s*)?)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_devices(line: str):
    """Extract one representative replica group (list of device ids)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, sz = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = (
            [int(x) for x in m.group(4).split(",")]
            if m.group(4)
            else list(range(len(dims)))
        )
        # iota list: devices arranged in `dims`, transposed by `perm`,
        # reshaped to [ng, sz]; reconstruct the full table.
        import numpy as np

        ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        return ids.reshape(ng, sz).tolist()
    m = _GROUPS_RE.search(line)
    if m:
        groups = []
        for grp in re.findall(r"\{([\d,\s]*)\}", "{" + m.group(1) + "}"):
            if grp.strip():
                groups.append([int(x) for x in grp.split(",")])
        return groups or None
    m = _SRC_TGT_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return [[int(a), int(b)] for a, b in pairs]
    return None


def _link_class(groups, pod_size: int) -> str:
    if not groups or pod_size <= 0:
        return "ici"
    for g in groups:
        pods = {d // pod_size for d in g}
        if len(pods) > 1:
            return "dci"
    return "ici"


def analyze_collectives(hlo_text: str, pod_size: int = 0) -> dict:
    """Sum per-device collective operand bytes by op kind and link class.

    Result-shape bookkeeping: all-gather results are divided by the group
    size to recover operand bytes; reduce-scatter operands are the result
    times group size (we parse result shapes, which is what HLO prints).
    """
    out = {
        "ops": 0, "ici_bytes": 0, "dci_bytes": 0,
        "by_kind": {},
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        groups = _group_devices(line)
        gsize = max((len(g) for g in groups), default=1) if groups else 1
        if kind == "all-gather":
            operand = nbytes // max(1, gsize)
        elif kind == "reduce-scatter":
            operand = nbytes * gsize
        else:
            operand = nbytes
        cls = _link_class(groups, pod_size)
        out["ops"] += 1
        out[f"{cls}_bytes"] += operand
        k = out["by_kind"].setdefault(kind, {"count": 0, "bytes": 0})
        k["count"] += 1
        k["bytes"] += operand
    return out


def roofline(
    flops_dev: float,
    hbm_bytes_dev: float,
    ici_bytes_dev: float,
    dci_bytes_dev: float,
    useful_flops_dev: float,
    hw: Hardware = HW,
    hbm_bytes_analytic: float | None = None,
) -> dict:
    """Three-term roofline (seconds) + dominant term + MFU-style fraction.

    Two memory terms are reported: ``memory`` uses HLO bytes-accessed (the
    prescribed formula; on the CPU backend it is pre-fusion and therefore
    pessimistic) and ``memory_analytic`` uses the documented min-traffic
    model (params + optimizer + activation saves + logits + caches).  The
    adjusted step time / fraction use the analytic term; both are in the
    tables so the conservative number stays visible.
    """
    t_comp = flops_dev / hw.peak_flops
    t_mem = hbm_bytes_dev / hw.hbm_bw
    t_coll = ici_bytes_dev / hw.ici_bw + dci_bytes_dev / hw.dci_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_step = max(terms.values())
    useful_t = useful_flops_dev / hw.peak_flops
    out = {
        **terms,
        "dominant": dominant,
        "t_step": t_step,
        "model_flops_ratio": (
            useful_flops_dev / flops_dev if flops_dev else 0.0
        ),
        "roofline_fraction": useful_t / t_step if t_step else 0.0,
    }
    if hbm_bytes_analytic is not None:
        t_mem_a = hbm_bytes_analytic / hw.hbm_bw
        adj = {"compute": t_comp, "memory": t_mem_a, "collective": t_coll}
        out["memory_analytic"] = t_mem_a
        out["dominant_adj"] = max(adj, key=adj.get)
        out["t_step_adj"] = max(adj.values())
        out["roofline_fraction_adj"] = (
            useful_t / out["t_step_adj"] if out["t_step_adj"] else 0.0
        )
    return out


def analytic_min_hbm(cfg, kind: str, batch: int, seq: int, mesh) -> float:
    """Documented min-HBM-traffic model, bytes per device per step.

    train:   params fwd+bwd reads + AdamW m/v/p read+write (fp32) +
             remat-saved activations (w+r) + layer hot intermediates +
             logits (w+r, fp32)
    prefill: params read + activations + full logits (the unembed is
             applied to every position -- a known inefficiency, see §Perf)
    decode:  params read + full KV/state cache read + 1-slot write
    """
    tp = mesh.shape.get("model", 1)
    dp = max(1, mesh.size // tp)
    p_shard = cfg.param_count() / tp
    toks = batch * seq / dp  # per-device tokens
    d, v = cfg.d_model, cfg.vocab_size

    # per-token per-layer intermediate traffic (bf16), TP-sharded
    per_tok = 0.0
    for k in cfg.pattern_kinds:
        if k in ("attn", "local"):
            hd = cfg.head_dim
            per_tok += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + d
            if cfg.moe_experts:
                f_act = (
                    cfg.moe_top_k * cfg.moe_d_ff
                    * cfg.moe_capacity_factor
                )
            else:
                f_act = cfg.d_ff * (2 if cfg.gated_mlp else 1)
            per_tok += 2 * f_act + d
        elif k == "rglru":
            r = cfg.rnn_width or d
            per_tok += 4 * r + 2 * cfg.d_ff + d
        elif k == "mlstm":
            per_tok += 6 * cfg.mlstm_expansion * d
        elif k == "slstm":
            per_tok += 8 * d + 2 * int(cfg.slstm_ff_factor * d)
    act_bytes = toks * (per_tok / tp) * 2  # bf16

    if kind == "train":
        # params: fwd read + bwd read (f32) ; opt: r+w of m, v, p (f32)
        param_traffic = p_shard * 4 * (2 + 6)
        remat_saves = toks * d * 2 * cfg.n_layers * 2  # save + reload
        logits = toks * (v / tp) * 4 * 2
        return param_traffic + 3 * act_bytes + remat_saves + logits
    if kind == "prefill":
        return p_shard * 4 + act_bytes + toks * (v / tp) * 4
    # decode: one token; dominated by weights + cache sweep
    cache_bytes = 0.0
    for k in cfg.pattern_kinds:
        if k == "attn":
            cache_bytes += (
                2 * cfg.max_cache * cfg.n_kv_heads * cfg.head_dim * 2
            )
        elif k == "local":
            cache_bytes += (
                2 * cfg.window * cfg.n_kv_heads * cfg.head_dim * 2
            )
        elif k == "mlstm":
            dn = cfg.mlstm_expansion * d
            cache_bytes += (dn // cfg.n_heads) * dn * 4
        elif k == "rglru":
            cache_bytes += (cfg.rnn_width or d) * 4 * cfg.conv_width
        elif k == "slstm":
            cache_bytes += 4 * d * 4
    cache_dev = cache_bytes * batch / dp / max(
        1, tp if cfg.n_kv_heads % tp == 0 else 1
    )
    return p_shard * 4 + cache_dev + act_bytes
