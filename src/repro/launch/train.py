"""LM training driver.

Runs any ``--arch`` (full or ``--smoke``) on the available devices with
the full substrate: deterministic data pipeline, AdamW, checkpointing with
atomic publish + resume, straggler monitoring, and either SPMD or
hierarchical mixed-precision gradient sync (the paper's technique).

CPU example (the end-to-end deliverable):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..ckpt.checkpoint import CheckpointManager
from ..configs import get_config
from ..data.tokens import TokenStream
from ..dist import Topology
from ..dist.fault import StragglerMonitor, suggest_checkpoint_period
from ..dist.sharding import param_specs, shardings
from ..models.lm import make_hier_train_step, make_train_step
from ..models.transformer import init_params
from ..opt.adam import AdamW


def make_cpu_mesh():
    n = len(jax.devices())
    return jax.make_mesh(
        (1, 1, n), ("pod", "data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-comm", choices=("spmd", "hier"),
                    default="spmd")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_cpu_mesh()
    opt = AdamW(lr=args.lr)

    def init_all():
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    mgr = None
    state, start_step = init_all(), 0
    if args.ckpt_dir:
        mgr = CheckpointManager(
            args.ckpt_dir, every=args.ckpt_every, keep=3
        )
        state, start_step = mgr.restore_or_init(init_all)
        if start_step:
            print(f"resumed from step {start_step}")

    pspecs = param_specs(state["params"], mesh)
    state["params"] = jax.device_put(
        state["params"], shardings(pspecs, mesh)
    )

    if args.grad_comm == "hier":
        # same axis filter as make_hier_train_step, so the printed plan
        # is the one the step actually syncs over
        dp = tuple(a for a in ("data", "pod") if a in mesh.shape)
        topo = Topology.from_mesh(mesh, data_axes=dp, batch_axes=())
        print(topo.describe())
        print(topo.plan("hier").describe())
        step_fn = make_hier_train_step(cfg, opt, mesh)
    else:
        step_fn = make_train_step(cfg, opt)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    stream = TokenStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )
    monitor = StragglerMonitor()
    print(
        "suggested ckpt period @1000 nodes: "
        f"{suggest_checkpoint_period(30.0, 1000):.0f}s"
    )

    params, opt_state = state["params"], state["opt"]
    losses = []
    for step in range(start_step, args.steps):
        batch = stream.batch(step)
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch
        )
        loss = float(metrics["loss"])
        monitor.record(0, time.time() - t0)
        losses.append(loss)
        if step % args.log_every == 0:
            print(
                f"step {step:5d} loss {loss:8.4f} "
                f"dt {time.time()-t0:6.2f}s"
            )
        if mgr:
            mgr.maybe_save(
                step + 1,
                {"params": params, "opt": opt_state,
                 "step": jnp.int32(step + 1)},
            )
    print(
        f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
        f"stragglers: {monitor.stragglers()}"
    )
    return losses


if __name__ == "__main__":
    main()
