"""Deprecated alias: the LM toy server moved to ``launch.lm_serve``.

The ``serve`` name belongs to the reconstruction service now
(``repro.serve.ReconServer``); this shim keeps old
``python -m repro.launch.serve`` invocations and imports working one
release longer.
"""
from __future__ import annotations

import warnings

from .lm_serve import main

__all__ = ["main"]

warnings.warn(
    "repro.launch.serve is deprecated: the LM toy server lives at "
    "repro.launch.lm_serve; the reconstruction service is repro.serve",
    DeprecationWarning,
    stacklevel=2,
)

if __name__ == "__main__":
    main()
