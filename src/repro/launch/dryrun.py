"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

No arrays are allocated: parameters, optimizer state, caches and batches
are ShapeDtypeStructs with NamedShardings; ``.lower().compile()`` proves
the distribution config is coherent (sharding match, collectives legal,
per-device memory known) and yields the cost/memory/collective numbers the
roofline analysis consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
  python -m repro.launch.dryrun --xct xct-brain [--multi-pod]

The XCT cells pair the compiled-HLO numbers with ``xct_analytic``, a
slot-exact cost model over the static blocked-ELL shapes.  Its wire
volumes are not hand-rolled here: they flow from ``dist.CommPlan``'s
per-link-class model, resolved against the cell's ``dist.Topology`` (so
the dry-run, the §Perf sweep and ``benchmarks/bench_comms.py`` can never
disagree about what a mode ships over ICI vs DCI).

Example -- the analytic model is pure accounting, usable without any
devices attached (a meshless two-level ladder, one CG iteration):

>>> from repro.core.geometry import XCTGeometry
>>> from repro.core.partition import PartitionConfig, estimate_plan
>>> from repro.core.recon import ReconConfig
>>> from repro.dist import Topology
>>> plan = estimate_plan(
...     XCTGeometry(n=512, n_angles=256),
...     PartitionConfig(n_data=16, tile=32, rows_per_block=64,
...                     nnz_per_stage=64),
... )
>>> topo = Topology.from_sizes([("model", 8, "ici"), ("data", 2, "dci")])
>>> an = xct_analytic(
...     plan, ReconConfig(precision="mixed", comm_mode="hier"), topo,
...     fuse=4, iters=1,
... )
>>> sorted(an) == ['dci_dev', 'dma_issues_dev', 'flops_dev', 'hbm_dev',
...                'ici_dev']
True
>>> an["dci_dev"] == an["ici_dev"] / 8  # ladder: 1/|socket| crosses DCI
True
"""
# The two lines below MUST precede any jax import: jax locks the device
# count on first init, and only the dry-run wants 512 placeholder devices.
import os  # noqa: E402

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_NAMES, SHAPES, get_config
from ..dist.sharding import batch_specs, cache_specs, param_specs, shardings
from ..models.lm import decode_step, loss_fn, make_train_step, prefill
from ..models.transformer import init_cache, init_params
from ..opt.adam import AdamW
from .hlo_analysis import analytic_min_hbm, analyze_collectives, roofline
from .mesh import make_production_mesh

DP_AXES = ("pod", "data")


def _sds_tree(tree, specs, mesh):
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            np.shape(leaf),
            leaf.dtype if hasattr(leaf, "dtype") else jnp.float32,
            sharding=NamedSharding(mesh, spec),
        ),
        tree,
        specs,
        is_leaf=lambda x: hasattr(x, "dtype") or hasattr(x, "shape"),
    )


def _abstract_params(cfg, mesh):
    params = jax.eval_shape(partial(init_params, cfg), jax.random.key(0))
    specs = param_specs(params, mesh)
    return _sds_tree(params, specs, mesh), specs


def _useful_flops(cfg, shape_kind, tokens, n_dev):
    n_active = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens / n_dev


def _recurrent_flops_correction(cfg, kind, batch, seq) -> float:
    """Per-device extra FLOPs for time-scanned recurrent cells.

    ``cost_analysis`` counts a while-loop body once; the layer stack is
    unrolled for the cost pass, but the *time* recurrence of mLSTM/sLSTM
    cannot be (T up to 512k), so the missing (T-1) body repetitions are
    added analytically.  RG-LRU uses an associative scan (tree-expanded in
    HLO) and needs no correction.  State tensors are modeled VMEM-resident
    (no HBM-byte correction; recorded in EXPERIMENTS.md notes).
    """
    if kind == "decode":
        return 0.0
    per_tok = 0.0
    d = cfg.d_model
    for k in cfg.pattern_kinds:
        if k == "mlstm":
            dn = cfg.mlstm_expansion * d
            hd = dn // cfg.n_heads
            per_tok += cfg.n_heads * (5 * hd * hd + 6 * hd)
        elif k == "slstm":
            per_tok += 8 * d * d + 25 * d
    mult = 3.0 if kind == "train" else 1.0  # fwd + ~2x bwd
    return per_tok * batch * (seq - 1) * mult


def _build_cell(cfg, kind, seq, batch, mesh, dp):
    """Assemble (jitted fn, abstract args, token count) for one cell."""
    params_sds, pspecs_tree = _abstract_params(cfg, mesh)
    ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = P(dp) if dp and batch % ndp == 0 else P()

    if cfg.embed_inputs:
        inputs = jax.ShapeDtypeStruct(
            (batch, seq), jnp.int32, sharding=NamedSharding(mesh, bspec)
        )
    else:
        espec = P(*(tuple(bspec) + (None, None))) if len(bspec) else P()
        inputs = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, espec),
        )
    labels = jax.ShapeDtypeStruct(
        (batch, seq), jnp.int32, sharding=NamedSharding(mesh, bspec)
    )

    if kind == "train":
        opt = AdamW()
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_specs = {"m": pspecs_tree, "v": pspecs_tree, "count": P()}
        opt_sds = _sds_tree(opt_sds, opt_specs, mesh)
        step = make_train_step(cfg, opt)
        fn = jax.jit(step, donate_argnums=(0, 1))
        args = (params_sds, opt_sds, {"inputs": inputs, "labels": labels})
        tokens = batch * seq
    elif kind == "prefill":
        fn = jax.jit(lambda p, i: prefill(p, cfg, i))
        args = (params_sds, inputs)
        tokens = batch * seq
    else:  # decode
        cache = jax.eval_shape(lambda: init_cache(cfg, batch))
        cspecs = cache_specs(cache, cfg, mesh, dp)
        cache_sds = _sds_tree(cache, cspecs, mesh)
        if cfg.embed_inputs:
            token = jax.ShapeDtypeStruct(
                (batch, 1), jnp.int32, sharding=NamedSharding(mesh, bspec)
            )
        else:
            espec = (
                P(*(tuple(bspec) + (None, None))) if len(bspec) else P()
            )
            token = jax.ShapeDtypeStruct(
                (batch, 1, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, espec),
            )
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            lambda p, c, t, q: decode_step(p, cfg, c, t, q),
            donate_argnums=(1,),
        )
        args = (params_sds, cache_sds, token, pos)
        tokens = batch
    return fn, args, tokens


def _hint_overrides(arch, dp, kind: str = "train"):
    """Sharding-hint config for the optimized (§Perf) variants.

    Score-sharding choice, from the §Perf measurements (iterations 3/5/7):
    kv divides the model axis -> shard kv; MQA (kv=1) and *prefill* cells
    -> query-time (context parallel; no backward resharding cost); train
    cells with total heads divisible -> merged-heads; else query-time.
    """
    cfg = get_config(arch)
    kv_ok = cfg.n_kv_heads % 16 == 0
    h_ok = cfg.n_heads % 16 == 0
    if kv_ok:
        q_shard, merge = False, False
    elif kind == "prefill" or cfg.n_kv_heads == 1:
        q_shard, merge = True, False
    elif h_ok:
        q_shard, merge = False, True
    else:
        q_shard, merge = True, False
    return {
        "shard_hints": True,
        "attn_heads_merge": merge,
        "attn_q_shard": q_shard,
        "dp_axes": dp,
    }


def _cost_numbers(arch, shape, multi_pod, n_layers, overrides=None):
    """FLOPs/bytes/collectives of a small *unrolled* variant (FD probe)."""
    seq, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = tuple(a for a in DP_AXES if a in mesh.shape)
    cfg = get_config(
        arch, max_cache=seq, scan_layers=False, n_layers=n_layers,
        remat="full" if kind == "train" else "none",
        **(overrides or {}),
    )
    fn, args, _ = _build_cell(cfg, kind, seq, batch, mesh, dp)
    with mesh:
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = analyze_collectives(
        compiled.as_text(), pod_size=256 if multi_pod else 0
    )
    return np.array([
        float(cost.get("flops", 0.0)),
        float(sum(v for k, v in cost.items()
                  if k.startswith("bytes accessed"))),
        float(coll["ici_bytes"]),
        float(coll["dci_bytes"]),
    ])


def lower_lm_cell(
    arch: str, shape: str, multi_pod: bool, fd_cost: bool = True,
    overrides: dict | None = None,
) -> dict:
    seq, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    dp = tuple(a for a in DP_AXES if a in mesh.shape)
    cfg = get_config(
        arch,
        max_cache=seq,
        remat="full" if kind == "train" else "none",
        **(overrides or {}),
    )
    if kind == "decode" and not cfg.sub_quadratic and shape == "long_500k":
        return {
            "status": "skipped(full-attention)",
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
        }

    fn, args, tokens = _build_cell(cfg, kind, seq, batch, mesh, dp)

    t0 = time.time()
    with mesh:
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    pod_size = 256 if multi_pod else 0
    coll = analyze_collectives(compiled.as_text(), pod_size=pod_size)

    # --- finite-difference cost correction ----------------------------
    # The full model is lowered with scanned layers (compact HLO, fast
    # compile, true memory analysis), but cost_analysis counts a scan body
    # once.  Two small UNROLLED probes give per-period cost exactly:
    #   total = F(1 period [+rem]) + (n_periods - 1) * [F(2p) - F(1p)]
    period = len(cfg.block_pattern)
    n_per, rem = divmod(cfg.n_layers, period)
    if fd_cost and n_per >= 1:
        f1 = _cost_numbers(
            arch, shape, multi_pod, period + rem, overrides
        )
        f2 = _cost_numbers(
            arch, shape, multi_pod, 2 * period + rem, overrides
        )
        # clamp: near-zero per-layer deltas can FD to small negatives
        totals = np.maximum(f1 + (n_per - 1) * (f2 - f1), 0.0)
        flops_dev, hbm_dev = float(totals[0]), float(totals[1])
        ici_b, dci_b = float(totals[2]), float(totals[3])
        cost_source = "fd(unrolled 1p/2p)"
    else:
        flops_dev = float(cost.get("flops", 0.0))
        hbm_dev = float(
            sum(v for k, v in cost.items()
                if k.startswith("bytes accessed"))
        )
        ici_b, dci_b = coll["ici_bytes"], coll["dci_bytes"]
        cost_source = "scanned(body-once)"
    flops_dev += _recurrent_flops_correction(cfg, kind, batch, seq) / n_dev

    rf = roofline(
        flops_dev,
        hbm_dev,
        ici_b,
        dci_b,
        _useful_flops(cfg, kind, tokens, n_dev),
        hbm_bytes_analytic=analytic_min_hbm(cfg, kind, batch, seq, mesh),
    )
    return {
        "cost_source": cost_source,
        "status": "ok",
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
            "out_bytes": getattr(mem, "output_size_in_bytes", None),
        },
        "flops_per_dev": flops_dev,
        "hbm_bytes_per_dev": hbm_dev,
        "ici_bytes_per_dev": ici_b,
        "dci_bytes_per_dev": dci_b,
        "collectives": coll,
        "roofline": rf,
    }


def lower_xct_cell(dataset: str, multi_pod: bool, iters: int = 2) -> dict:
    """Dry-run the XCT CG step at full dataset scale (abstract shards)."""
    from ..configs.xct_datasets import DATASETS
    from ..core.geometry import XCTGeometry
    from ..core.partition import (
        PartitionConfig, default_socket, estimate_plan,
    )
    from ..core.recon import ReconConfig, Reconstructor

    from ..dist import Topology

    ds = DATASETS[dataset]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    # Paper's optimal strategy: data-parallel only until memory fits; the
    # remaining axes carry batch parallelism over slices.
    p_data = min(ds.p_data, n_dev)
    if multi_pod and p_data >= 512:
        data_axes, batch_axes = ("model", "data", "pod"), ()
    elif multi_pod:
        data_axes, batch_axes = ("model", "data"), ("pod",)
    else:
        data_axes, batch_axes = ("model", "data"), ()
        p_data = min(p_data, 256)
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    pcfg = PartitionConfig(
        n_data=p_data, tile=32, rows_per_block=64, nnz_per_stage=64,
        socket=default_socket(p_data, mesh.shape["model"]),
    )
    plan = estimate_plan(geo, pcfg)
    rcfg = ReconConfig(precision="mixed_bf16", comm_mode="hier", fuse=16,
                       use_ref=True)
    topo = Topology.from_mesh(
        mesh, data_axes=data_axes, batch_axes=batch_axes
    )
    rec = Reconstructor(plan, topology=topo, cfg=rcfg, abstract=True)
    n_batch = rec.n_batch
    y_slices = rcfg.fuse * n_batch  # one fused I/O batch per batch group
    t0 = time.time()
    lowered, compiled = rec.lower_cg(y_slices, iters=iters)
    t1 = time.time()
    mem = compiled.memory_analysis()
    coll = analyze_collectives(
        compiled.as_text(), pod_size=256 if multi_pod else 0
    )
    an = xct_analytic(plan, rcfg, topo, y_slices // n_batch, iters)
    # useful flops: 2 flops/nnz * 2 ops (proj+back) * fuse slices * iters
    nnz_total = geo.n_rays * 1.195 * ds.n
    useful = 4.0 * nnz_total * (y_slices // n_batch) * iters / p_data
    rf = roofline(
        an["flops_dev"], an["hbm_dev"],
        an["ici_dev"] if not multi_pod else an["ici_dev"],
        an["dci_dev"] if multi_pod else 0.0,
        useful,
        hbm_bytes_analytic=an["hbm_dev"],
    )
    return {
        "status": "ok", "arch": dataset, "shape": f"cg{iters}x{y_slices}sl",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "comm_mode": rcfg.comm_mode,
        "compile_s": round(t1 - t0, 1),
        "p_data": p_data,
        "memory": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
        },
        "flops_per_dev": an["flops_dev"],
        "hbm_bytes_per_dev": an["hbm_dev"],
        "ici_bytes_per_dev": an["ici_dev"],
        "dci_bytes_per_dev": an["dci_dev"] if multi_pod else 0.0,
        "collectives_hlo": coll,
        "analytic": an,
        "roofline": rf,
    }


def socket_sweep(
    dataset: str = "xct-brain",
    p_data: int = 512,
    fuse: int = 16,
    comm_bytes: int = 2,
) -> dict:
    """ROADMAP sweep: ``PartitionConfig(socket=1)`` vs ``socket=fast``.

    Compares the modeled hier-sparse wire volume of the legacy scattered
    chunk layout (socket members' footprints ~ independent draws) against
    the socket-aware layout (members own consecutive Hilbert chunks;
    adjacent-chunk union model, ``core.partition.estimate_hier_sparse``)
    at production scale, on the production ladder
    (``xct_perf.sweep_topology``).  The winner is what
    ``core.partition.default_socket`` hands every driver.

    >>> sw = socket_sweep()
    >>> sw["fast"]
    16
    >>> sw["socket=16"]["dci"] < sw["socket=1"]["dci"]
    True
    >>> sw["winner"]
    16
    """
    from ..configs.xct_datasets import DATASETS
    from ..core.geometry import XCTGeometry
    from ..core.partition import PartitionConfig, estimate_plan
    from .xct_perf import comm_volume, sweep_topology

    ds = DATASETS[dataset]
    geo = XCTGeometry(n=ds.n, n_angles=ds.k)
    topo = sweep_topology(p_data)
    fast = topo.levels[0].size
    out = {"dataset": dataset, "p_data": p_data, "fast": fast}
    for socket in (1, fast):
        plan = estimate_plan(
            geo,
            PartitionConfig(
                n_data=p_data, tile=32, rows_per_block=64,
                nnz_per_stage=64, socket=socket,
            ),
        )
        out[f"socket={socket}"] = comm_volume(
            plan, "hier-sparse", fuse, comm_bytes, topo
        )
    key = "dci" if out[f"socket={fast}"]["dci"] else "ici"
    out["winner"] = (
        fast
        if out[f"socket={fast}"][key] < out["socket=1"][key]
        else 1
    )
    return out


def xct_analytic(plan, rcfg, topo, fuse: int, iters: int) -> dict:
    """Slot-exact per-device cost model for the XCT CG step.

    The minibatch pipeline and CG loop are lax.scans (counted once by
    cost_analysis), so FLOPs/bytes are computed from the static blocked-ELL
    shapes instead, via the shared ``kernels.traffic.spmm_traffic`` model
    (2 FLOPs per nnz slot per fused slice, 4 B/slot operator reads, and
    the staging term matching ``rcfg.staging`` -- the default in-kernel
    staging has no HBM window round trip, so modeled arithmetic intensity
    is strictly higher than the legacy gather baseline).  The exchange
    volume per reduction is whatever ``topo.plan(rcfg.comm_mode)`` models
    for each link class -- one source of truth shared with the runtime
    collectives and ``benchmarks/bench_comms.py``.

    ``dma_issues_dev`` counts the window-staging copies the kernel
    issues (one per run-length segment under the default
    ``rcfg.dma="coalesced"``, one per winmap row under ``"per_row"``)
    so rooflines can price the fixed per-copy overhead with
    ``kernels.traffic.dma_issue_seconds``.
    """
    from ..core.partition import exchange_volume_params
    from ..core.precision import get_policy
    from ..kernels.traffic import op_segments_per_stage, spmm_traffic

    pol = get_policy(rcfg.precision)
    sb, cb = pol.storage_bytes, pol.comm_bytes
    wire = getattr(rcfg, "wire", "native")
    out = {"flops_dev": 0.0, "hbm_dev": 0.0, "ici_dev": 0.0,
           "dci_dev": 0.0, "dma_issues_dev": 0.0}
    for op in (plan.proj, plan.back):
        _, b, s, r, k = op.inds.shape
        segs = op_segments_per_stage(op)
        t = spmm_traffic(
            b, s, r, k, op.winmap.shape[-1], fuse, storage_bytes=sb,
            vals_bytes=pol.vals_bytes,
            staging=getattr(rcfg, "staging", "fused"),
            dma=getattr(rcfg, "dma", "coalesced"),
            segments_per_stage=segs,
        )
        out["flops_dev"] += iters * t["flops"]
        out["hbm_dev"] += iters * t["hbm_bytes"]
        out["dma_issues_dev"] += iters * t["dma_issues"]
        dense = float(op.n_rows_pad) * fuse * cb
        params = (
            exchange_volume_params(op, topo)
            if rcfg.comm_mode in ("sparse", "hier-sparse") else {}
        )
        wl = topo.plan(
            rcfg.comm_mode, wire=wire, comm_bytes=cb, **params
        ).wire_bytes_by_link(dense)
        out["ici_dev"] += iters * wl.get("ici", 0.0)
        out["dci_dev"] += iters * wl.get("dci", 0.0)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--xct")
    ap.add_argument(
        "--socket-sweep", action="store_true",
        help="socket=1 vs socket=fast hier-sparse volume at xct scale",
    )
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument(
        "--hints", action="store_true",
        help="apply §Perf sharding hints (optimized variant)",
    )
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh0 = make_production_mesh(multi_pod=args.multi_pod)
    dp0 = tuple(a for a in DP_AXES if a in mesh0.shape)

    def ov(arch, shape):
        if not args.hints:
            return None
        return _hint_overrides(arch, dp0, SHAPES[shape][2])

    results = []

    def run(fn, *a):
        try:
            r = fn(*a)
        except Exception as e:  # noqa: BLE001 -- record & continue
            r = {
                "status": f"error: {type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        results.append(r)
        print(json.dumps(r, default=str))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)

    if args.socket_sweep:
        run(socket_sweep, args.xct or "xct-brain")
    elif args.xct:
        run(lower_xct_cell, args.xct, args.multi_pod)
    elif args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                print(f"--- {arch} x {shape} ---", flush=True)
                run(
                    lower_lm_cell, arch, shape, args.multi_pod, True,
                    ov(arch, shape),
                )
    else:
        run(
            lower_lm_cell, args.arch, args.shape, args.multi_pod, True,
            ov(args.arch, args.shape),
        )


if __name__ == "__main__":
    main()
