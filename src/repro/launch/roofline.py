"""Roofline table generator: dry-run JSON artifacts -> markdown tables.

  PYTHONPATH=src python -m repro.launch.roofline \
      results/dryrun_single_pod.json [results/dryrun_single_pod_hints.json]
"""
from __future__ import annotations

import json
import sys


def fmt_row(r):
    if r["status"] != "ok":
        return (
            f"| {r.get('arch','?'):22s} | {r.get('shape','?'):12s} | "
            f"{r['status']} ||||||||"
        )
    rf = r["roofline"]
    return (
        f"| {r['arch']:22s} | {r['shape']:12s} "
        f"| {rf['compute']:9.3f} | {rf['memory']:9.2f} "
        f"| {rf.get('memory_analytic', 0):9.4f} "
        f"| {rf['collective']:9.3f} | {rf.get('dominant_adj', '?'):10s} "
        f"| {rf.get('t_step_adj', 0):8.3f} "
        f"| {rf['model_flops_ratio']:5.2f} "
        f"| {rf.get('roofline_fraction_adj', 0):6.3f} |"
    )


HEADER = (
    "| arch | shape | compute s | mem(HLO) s | mem(analytic) s | "
    "collective s | dominant | t_step s | MF ratio | roofline frac |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def emit(path):
    rows = json.load(open(path))
    print(f"\n### {path}\n")
    print(HEADER)
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        fr = [r["roofline"].get("roofline_fraction_adj", 0) for r in ok]
        print(
            f"\n{len(ok)} ok / {len(rows)} cells; "
            f"roofline fraction: min {min(fr):.3f} "
            f"median {sorted(fr)[len(fr)//2]:.3f} max {max(fr):.3f}"
        )


if __name__ == "__main__":
    for p in sys.argv[1:]:
        emit(p)
