"""Production mesh construction.

A function, not a module-level constant, so importing never touches jax
device state.  Mesh axes (fast -> slow physical links):

  "model" -- minor ICI axis: tensor/expert parallelism (XCT: in-slice data
             parallelism's fastest reduction level, the paper's "socket")
  "data"  -- major ICI axis: data parallelism (XCT: "node" level)
  "pod"   -- inter-pod DCI: outermost data parallelism (XCT: "global")
"""
from __future__ import annotations

import jax

from ..dist.topology import LINK_CLASSES

__all__ = ["make_production_mesh", "mesh_axis_classes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_axis_classes(multi_pod: bool = False) -> dict:
    """Link-speed class per axis (used by the roofline collective model).

    Derived from the canonical ``dist.topology.LINK_CLASSES`` table so
    mesh construction and Topology volume attribution cannot drift.
    """
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {a: LINK_CLASSES[a] for a in axes}
