"""Batched serving driver: prefill + decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.lm import decode_step, prefill
from ..models.transformer import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(
        args.arch, smoke=args.smoke,
        max_cache=args.prompt_len + args.gen,
    )
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)

    if cfg.embed_inputs:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    else:
        prompts = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )

    pf = jax.jit(lambda p, i: prefill(p, cfg, i))
    dc = jax.jit(
        lambda p, c, t, q: decode_step(p, cfg, c, t, q),
        donate_argnums=(1,),
    )

    t0 = time.time()
    last_logits, cache = pf(params, prompts)
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t1 = time.time()
    out_tokens = [np.asarray(tok)]
    pos = args.prompt_len
    for i in range(args.gen - 1):
        step_in = (
            tok
            if cfg.embed_inputs
            else jax.random.normal(
                key, (args.batch, 1, cfg.d_model), jnp.bfloat16
            )
        )
        tok, cache, _ = dc(params, cache, step_in, jnp.int32(pos))
        out_tokens.append(np.asarray(tok))
        pos += 1
    jax.block_until_ready(tok)
    t2 = time.time()
    gen = np.concatenate(out_tokens, axis=1)
    tput = args.batch * (args.gen - 1) / max(1e-9, t2 - t1)
    print(f"prefill {t1-t0:.2f}s, decode {t2-t1:.2f}s "
          f"({tput:.1f} tok/s), sample row: {gen[0][:12]}")
    return gen


if __name__ == "__main__":
    main()
