"""Drivers: reconstruction, training, serving, dry-run lowering, perf sweeps."""
