"""Exporters: Chrome trace-event JSON (Perfetto) + schema validation.

``chrome_trace`` turns a :class:`~repro.obs.trace.Tracer`'s events into
the Chrome trace-event JSON format (the ``{"traceEvents": [...]}``
object form), which https://ui.perfetto.dev loads directly:

* spans become complete events (``ph="X"``, ``ts``/``dur`` in
  microseconds, timestamps rebased to the earliest event so traces are
  origin-independent);
* instants become ``ph="i"`` markers;
* lanes become Chrome *threads*: one ``tid`` per recording thread by
  default, or per explicit ``lane=`` (serve's ``tenant:<name>`` lanes),
  each named by a ``ph="M"`` ``thread_name`` metadata event and sorted
  deterministically.

``validate_chrome_trace`` checks a document against the checked-in
schema ``chrome_trace.schema.json`` with a dependency-free subset
validator (type / required / properties / items / enum / minimum),
plus the semantic rule a type-level schema cannot express: every
``"X"`` event must carry ``ts`` and ``dur``.  The CI obs-smoke job and
``tests/test_obs.py`` run exactly this function over freshly emitted
traces.
"""
from __future__ import annotations

import json
import os

from .trace import Tracer

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "load_schema",
    "validate_chrome_trace",
    "SchemaError",
]

SCHEMA_PATH = os.path.join(
    os.path.dirname(__file__), "chrome_trace.schema.json"
)


def chrome_trace(tracer: Tracer) -> dict:
    """Tracer events -> Chrome trace-event JSON object (Perfetto-ready)."""
    with tracer._lock:
        events = list(tracer.events)
    lanes: dict[str, int] = {}

    def lane_of(e) -> str:
        return e["lane"] if e["lane"] is not None else (
            f"{e['thread']} ({e['thread_id']})"
        )

    for e in events:
        lanes.setdefault(lane_of(e), 0)
    for i, name in enumerate(sorted(lanes), start=1):
        lanes[name] = i
    t_origin = min((e["t0"] for e in events), default=0.0)

    out: list[dict] = []
    for name in sorted(lanes):
        out.append(
            {
                "ph": "M",
                "pid": 1,
                "tid": lanes[name],
                "name": "thread_name",
                "args": {"name": name},
            }
        )
    for e in events:
        args = dict(e["attrs"])
        if e["parent"] is not None:
            args["parent"] = e["parent"]
        rec = {
            "pid": 1,
            "tid": lanes[lane_of(e)],
            "name": e["name"],
            "cat": e["name"].split("/", 1)[0],
            "ts": (e["t0"] - t_origin) * 1e6,
            "args": args,
        }
        if e["kind"] == "instant":
            rec["ph"] = "i"
            rec["s"] = "t"
        else:
            rec["ph"] = "X"
            rec["dur"] = (e["t1"] - e["t0"]) * 1e6
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer | None = None) -> str:
    """Serialize the tracer (default: process tracer) to ``path``."""
    if tracer is None:
        from .trace import get_tracer

        tracer = get_tracer()
    doc = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


# --------------------------------------------------------------------- #
# schema validation (dependency-free subset of JSON Schema)
# --------------------------------------------------------------------- #
class SchemaError(ValueError):
    """A document does not satisfy the trace schema."""


def load_schema(path: str | None = None) -> dict:
    with open(path or SCHEMA_PATH) as f:
        return json.load(f)


_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
}


def _check(doc, schema: dict, where: str):
    typ = schema.get("type")
    if typ is not None:
        py = _TYPES[typ]
        ok = isinstance(doc, py) and not (
            typ in ("number", "integer") and isinstance(doc, bool)
        )
        if not ok:
            raise SchemaError(f"{where}: expected {typ}, got "
                              f"{type(doc).__name__}")
    if "enum" in schema and doc not in schema["enum"]:
        raise SchemaError(f"{where}: {doc!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(doc, (int, float)) \
            and not isinstance(doc, bool) and doc < schema["minimum"]:
        raise SchemaError(f"{where}: {doc} < minimum "
                          f"{schema['minimum']}")
    if isinstance(doc, dict):
        for req in schema.get("required", ()):
            if req not in doc:
                raise SchemaError(f"{where}: missing required key "
                                  f"{req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in doc:
                _check(doc[key], sub, f"{where}.{key}")
    if isinstance(doc, list) and "items" in schema:
        for i, item in enumerate(doc):
            _check(item, schema["items"], f"{where}[{i}]")


def validate_chrome_trace(doc: dict, schema: dict | None = None):
    """Raise :class:`SchemaError` unless ``doc`` satisfies the checked-in
    trace schema + the X-events-carry-ts/dur semantic rule.  Returns
    ``doc`` so calls chain."""
    _check(doc, schema or load_schema(), "$")
    for i, e in enumerate(doc.get("traceEvents", [])):
        if e.get("ph") == "X" and ("ts" not in e or "dur" not in e):
            raise SchemaError(
                f"$.traceEvents[{i}]: complete event missing ts/dur"
            )
        if e.get("ph") == "i" and "ts" not in e:
            raise SchemaError(
                f"$.traceEvents[{i}]: instant event missing ts"
            )
    return doc
