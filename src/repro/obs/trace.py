"""Zero-dependency span tracer: the timing spine of the whole stack.

Every host-side hot path -- ``Reconstructor.reconstruct``/``stage_sino``,
the streaming driver and its prefetch thread, serve's batch drain -- times
itself through :func:`span` instead of ad-hoc ``time.perf_counter()``
pairs, so one run produces one coherent, nestable, thread-aware timeline
on one monotonic clock.  Design rules:

* **Spans always measure, the tracer optionally records.**  A
  :class:`Span` reads the clock on enter/exit regardless of tracing
  state (its ``duration_s`` is what populates ``StreamResult`` /
  ``JobTelemetry``), but the finished event is appended to the tracer
  only while :func:`enable` is active -- with tracing off the cost is
  two clock reads per span, on paths that run once per *slab*, never
  per row (``bench_spmm``'s kernel path is untouched; the bench gate
  pins that).
* **Thread-aware lanes.**  Events carry the recording thread (the
  prefetch worker's loads land on their own lane) plus an optional
  explicit ``lane=`` (serve uses ``tenant:<name>`` so a multi-tenant
  drain renders one row per tenant in Perfetto).
* **Nesting is tracked, not inferred.**  Each event records its
  ``depth`` and ``parent`` span name (per-thread stack), which is what
  lets ``obs.drift`` sum a phase without double-counting a
  ``recon/solve`` nested inside a ``stream/solve``.
* **Deterministic under a fake clock.**  ``Tracer(clock=...)`` injects
  the time source; tests assert exact timestamps with no ``time.*``
  calls (see ``tests/test_obs.py``).
* **Device-true timings on demand.**  ``Span.fence(value)`` blocks on
  ``jax.block_until_ready`` so an async dispatch cannot end a span
  early; it is a no-op when jax is absent.

Span taxonomy (the names ``obs.drift`` and the CI obs-smoke assert on)
is tabulated in ``docs/observability.md``.

Doctest -- nesting, fake clock, exact math:

>>> t = Tracer(enabled=True, clock=iter(range(100)).__next__)
>>> with t.span("stream/slab", slab=0):
...     with t.span("stream/solve") as sp:
...         pass
>>> [(e["name"], e["t0"], e["t1"], e["parent"]) for e in t.events]
[('stream/solve', 1, 2, 'stream/slab'), ('stream/slab', 0, 3, None)]
>>> sp.duration_s
1
"""
from __future__ import annotations

import threading

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "enable",
    "disable",
    "span",
    "instant",
    "reset",
]


def _default_clock():
    import time

    return time.perf_counter()


class Span:
    """One timed region.  Use as a context manager; read ``duration_s``
    after exit.  An exception propagating through the span is recorded
    in its attrs as ``exception=<type name>`` (the serve failure-
    telemetry contract: the failing span names what killed it)."""

    __slots__ = ("name", "attrs", "lane", "t0", "t1", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, lane, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.lane = lane
        self.attrs = attrs
        self.t0 = None
        self.t1 = None

    @property
    def duration_s(self):
        """Wall seconds between enter and exit (``None`` while open)."""
        if self.t0 is None or self.t1 is None:
            return None
        return self.t1 - self.t0

    def fence(self, value):
        """Block until ``value``'s device computation lands (device-true
        span ends).  Returns ``value``; no-op without jax."""
        try:
            import jax

            jax.block_until_ready(value)
        except ImportError:  # pragma: no cover - jax is a repo dep
            pass
        return value

    def __enter__(self):
        self.t0 = self._tracer._clock()
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.attrs["exception"] = exc_type.__name__
        self.t1 = self._tracer._clock()
        self._tracer._pop(self)
        return False


class Tracer:
    """Collects finished spans + instants; exported by ``obs.export``.

    Args:
      enabled: record events (spans still *measure* when ``False``).
      clock: monotonic-seconds callable (default ``time.perf_counter``;
        inject a fake for deterministic tests).
    """

    def __init__(self, enabled: bool = False, clock=None):
        self.enabled = bool(enabled)
        self._clock = clock or _default_clock
        self._lock = threading.Lock()
        self._local = threading.local()
        self.events: list[dict] = []

    # ------------------------------------------------------------------ #
    # recording
    # ------------------------------------------------------------------ #
    def span(self, name: str, *, lane: str | None = None, **attrs) -> Span:
        """A nestable timed region; see :class:`Span`."""
        return Span(self, name, lane, attrs)

    def instant(self, name: str, *, lane: str | None = None, **attrs):
        """A zero-duration marker event (Chrome ``ph="i"``): annotations
        like the modeled exchange volumes a solve just implied."""
        if not self.enabled:
            return
        now = self._clock()
        th = threading.current_thread()
        with self._lock:
            self.events.append(
                {
                    "name": name,
                    "t0": now,
                    "t1": now,
                    "lane": lane,
                    "thread": th.name,
                    "thread_id": th.ident,
                    "depth": len(self._stack()),
                    "parent": self._stack()[-1].name
                    if self._stack() else None,
                    "attrs": dict(attrs),
                    "kind": "instant",
                }
            )

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, sp: Span):
        if self.enabled:
            self._stack().append(sp)

    def _pop(self, sp: Span):
        if not self.enabled:
            return
        st = self._stack()
        parent = None
        if st and st[-1] is sp:
            st.pop()
            parent = st[-1].name if st else None
        th = threading.current_thread()
        with self._lock:
            self.events.append(
                {
                    "name": sp.name,
                    "t0": sp.t0,
                    "t1": sp.t1,
                    "lane": sp.lane,
                    "thread": th.name,
                    "thread_id": th.ident,
                    "depth": len(st),
                    "parent": parent,
                    "attrs": dict(sp.attrs),
                    "kind": "span",
                }
            )

    # ------------------------------------------------------------------ #
    # interrogation
    # ------------------------------------------------------------------ #
    def spans(self, name: str | None = None) -> list[dict]:
        """Finished span events (optionally filtered by exact name)."""
        with self._lock:
            evs = [e for e in self.events if e["kind"] == "span"]
        if name is not None:
            evs = [e for e in evs if e["name"] == name]
        return evs

    def total_s(self, name: str) -> float:
        """Summed duration of every span with ``name``."""
        return sum(e["t1"] - e["t0"] for e in self.spans(name))

    def reset(self):
        with self._lock:
            self.events.clear()


# --------------------------------------------------------------------- #
# the process-default tracer (what the instrumented hot paths use)
# --------------------------------------------------------------------- #
_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-default tracer (tests); returns the old one."""
    global _tracer
    old, _tracer = _tracer, tracer
    return old


def enable(clock=None) -> Tracer:
    """Turn on recording on the default tracer (fresh event list)."""
    global _tracer
    _tracer = Tracer(enabled=True, clock=clock)
    return _tracer


def disable() -> Tracer:
    """Stop recording (spans keep measuring for their callers)."""
    _tracer.enabled = False
    return _tracer


def reset():
    _tracer.reset()


def span(name: str, *, lane: str | None = None, **attrs) -> Span:
    """A span on the process-default tracer (the instrumentation entry
    point: ``with span("stream/solve", slab=j0) as sp: ...``)."""
    return _tracer.span(name, lane=lane, **attrs)


def instant(name: str, *, lane: str | None = None, **attrs):
    """An instant marker on the process-default tracer."""
    _tracer.instant(name, lane=lane, **attrs)
