"""repro.obs: unified tracing + metrics spine.

* :mod:`~repro.obs.trace` -- nestable, thread-aware spans on one
  monotonic clock (``with span("stream/solve", slab=j0): ...``).
* :mod:`~repro.obs.metrics` -- counters / gauges / histograms with a
  Prometheus text exposition.
* :mod:`~repro.obs.export` -- Chrome trace-event JSON (Perfetto) +
  schema validation against the checked-in
  ``chrome_trace.schema.json``.
* :mod:`~repro.obs.drift` -- modeled-vs-measured per-phase drift
  report joining span totals against the traffic / comm-volume models.

See ``docs/observability.md`` for the span taxonomy and workflows.
"""
from .drift import drift_report, measured_phases, modeled_phases
from .export import (
    chrome_trace,
    load_schema,
    validate_chrome_trace,
    write_chrome_trace,
)
from .metrics import Metrics, get_metrics, set_metrics
from .trace import (
    Span,
    Tracer,
    disable,
    enable,
    get_tracer,
    instant,
    set_tracer,
    span,
)

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "enable",
    "disable",
    "span",
    "instant",
    "Metrics",
    "get_metrics",
    "set_metrics",
    "chrome_trace",
    "write_chrome_trace",
    "load_schema",
    "validate_chrome_trace",
    "drift_report",
    "measured_phases",
    "modeled_phases",
]
