"""Metrics registry: counters / gauges / histograms + Prometheus text.

The numeric side of the observability spine: where :mod:`~repro.obs.trace`
answers *when*, this answers *how much* -- DMA issues modeled per solve,
comm bytes by link class, plan-cache hits/misses, serve queue depth.
Zero dependencies; label sets are plain kwargs; rendering follows the
Prometheus text exposition format (``# TYPE`` headers, sorted series, so
two identical registries render byte-identical text --
``ReconServer.metrics_text()`` serves the snapshot).

Metric names used by the wired paths (see ``docs/observability.md``):

  ``dma_issues_total{op=}``        modeled window-DMA issues per solve
  ``comm_bytes_total{link=}``      modeled wire bytes (ici / dci)
  ``plan_cache_hits_total`` / ``plan_cache_misses_total`` /
  ``plan_cache_evictions_total``   serve plan-cache outcomes
  ``serve_jobs_total{status=}``    terminal job states
  ``serve_queue_depth``            gauge, sampled at submit/step
  ``stream_slabs_total``           slabs drained by the streaming driver

Doctest -- deterministic exposition:

>>> m = Metrics()
>>> m.inc("jobs_total", 2, status="done")
>>> m.inc("jobs_total", status="failed")
>>> m.set_gauge("queue_depth", 3)
>>> m.observe("solve_seconds", 0.5, buckets=(0.1, 1.0))
>>> print(m.render_prometheus())
# TYPE jobs_total counter
jobs_total{status="done"} 2
jobs_total{status="failed"} 1
# TYPE queue_depth gauge
queue_depth 3
# TYPE solve_seconds histogram
solve_seconds_bucket{le="0.1"} 0
solve_seconds_bucket{le="1"} 1
solve_seconds_bucket{le="+Inf"} 1
solve_seconds_sum 0.5
solve_seconds_count 1
"""
from __future__ import annotations

import threading

__all__ = [
    "Metrics",
    "get_metrics",
    "set_metrics",
    "inc",
    "set_gauge",
    "observe",
    "render_prometheus",
    "reset",
]

DEFAULT_BUCKETS = (0.001, 0.01, 0.1, 1.0, 10.0, 60.0)


def _fmt(v: float) -> str:
    """Prometheus-style number: integers without the trailing ``.0``."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def _key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _series(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Metrics:
    """A registry of counters, gauges and histograms (thread-safe)."""

    def __init__(self):
        self._lock = threading.Lock()
        # name -> {label key tuple -> value}
        self._counters: dict[str, dict] = {}
        self._gauges: dict[str, dict] = {}
        # name -> {label key tuple -> {"buckets": tuple, "counts": list,
        #                              "sum": float, "count": int}}
        self._hists: dict[str, dict] = {}

    # ------------------------------------------------------------------ #
    # writes
    # ------------------------------------------------------------------ #
    def inc(self, name: str, value: float = 1.0, **labels):
        """Add ``value`` (>= 0) to the counter series."""
        if value < 0:
            raise ValueError(f"counter {name} cannot decrease ({value})")
        with self._lock:
            s = self._counters.setdefault(name, {})
            k = _key(labels)
            s[k] = s.get(k, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, **labels):
        with self._lock:
            self._gauges.setdefault(name, {})[_key(labels)] = float(value)

    def observe(self, name: str, value: float, buckets=None, **labels):
        """Record one observation into the histogram series.  ``buckets``
        are upper bounds (ascending); fixed per series at first use."""
        with self._lock:
            s = self._hists.setdefault(name, {})
            k = _key(labels)
            h = s.get(k)
            if h is None:
                bs = tuple(buckets if buckets is not None
                           else DEFAULT_BUCKETS)
                h = s[k] = {"buckets": bs, "counts": [0] * len(bs),
                            "sum": 0.0, "count": 0}
            v = float(value)
            for i, ub in enumerate(h["buckets"]):
                if v <= ub:
                    h["counts"][i] += 1
            h["sum"] += v
            h["count"] += 1

    # ------------------------------------------------------------------ #
    # reads
    # ------------------------------------------------------------------ #
    def get(self, name: str, **labels) -> float:
        """Current value of a counter or gauge series (0 if unseen)."""
        k = _key(labels)
        with self._lock:
            if name in self._counters:
                return self._counters[name].get(k, 0.0)
            if name in self._gauges:
                return self._gauges[name].get(k, 0.0)
        return 0.0

    def snapshot(self) -> dict:
        """Plain-dict copy: ``{"counters": {series: v}, "gauges": ...}``
        (series rendered as the Prometheus sample name)."""
        with self._lock:
            out = {"counters": {}, "gauges": {}, "histograms": {}}
            for name, s in self._counters.items():
                for k, v in s.items():
                    out["counters"][_series(name, k)] = v
            for name, s in self._gauges.items():
                for k, v in s.items():
                    out["gauges"][_series(name, k)] = v
            for name, s in self._hists.items():
                for k, h in s.items():
                    out["histograms"][_series(name, k)] = {
                        "sum": h["sum"], "count": h["count"],
                    }
            return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (sorted: byte-deterministic)."""
        lines: list[str] = []
        with self._lock:
            for name in sorted(self._counters):
                lines.append(f"# TYPE {name} counter")
                for k in sorted(self._counters[name]):
                    lines.append(
                        f"{_series(name, k)} "
                        f"{_fmt(self._counters[name][k])}"
                    )
            for name in sorted(self._gauges):
                lines.append(f"# TYPE {name} gauge")
                for k in sorted(self._gauges[name]):
                    lines.append(
                        f"{_series(name, k)} "
                        f"{_fmt(self._gauges[name][k])}"
                    )
            for name in sorted(self._hists):
                lines.append(f"# TYPE {name} histogram")
                for k in sorted(self._hists[name]):
                    h = self._hists[name][k]
                    # counts are already cumulative (observe increments
                    # every bucket whose upper bound admits the value)
                    for ub, c in zip(h["buckets"], h["counts"]):
                        lines.append(
                            f"{_series(name + '_bucket', k + (('le', _fmt(ub)),))} {c}"
                        )
                    lines.append(
                        f"{_series(name + '_bucket', k + (('le', '+Inf'),))} "
                        f"{h['count']}"
                    )
                    lines.append(
                        f"{_series(name + '_sum', k)} {_fmt(h['sum'])}"
                    )
                    lines.append(
                        f"{_series(name + '_count', k)} {h['count']}"
                    )
        return "\n".join(lines)

    def reset(self):
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_metrics = Metrics()


def get_metrics() -> Metrics:
    return _metrics


def set_metrics(metrics: Metrics) -> Metrics:
    """Swap the process-default registry (tests); returns the old one."""
    global _metrics
    old, _metrics = _metrics, metrics
    return old


def inc(name: str, value: float = 1.0, **labels):
    _metrics.inc(name, value, **labels)


def set_gauge(name: str, value: float, **labels):
    _metrics.set_gauge(name, value, **labels)


def observe(name: str, value: float, buckets=None, **labels):
    _metrics.observe(name, value, buckets=buckets, **labels)


def render_prometheus() -> str:
    return _metrics.render_prometheus()


def reset():
    _metrics.reset()
