"""Drift detection: join measured span totals against the cost models.

The repo prices every design decision with closed-form models --
``kernels.traffic.spmm_traffic`` (HBM bytes + DMA issues),
``kernels.traffic.dma_issue_seconds`` (issue overhead),
``launch.xct_perf.comm_volume`` over ``CommPlan.resolve`` (wire bytes by
link class) -- and the autotuner's modeled tier picks configs from them
alone.  This module asks the follow-up question the ROADMAP's *measured
tier* needs answered: **does the wall clock agree?**

:func:`drift_report` joins two sides:

* **measured** -- span totals from :mod:`~repro.obs.trace`, summed per
  phase via the span taxonomy (``stream/solve`` and ``recon/solve`` ->
  ``solve``; ``stream/load`` -> ``load``; ``stream/stage`` /
  ``stream/upload`` / ``recon/stage`` -> ``upload``).  A span nested
  inside a same-phase parent is skipped, so a ``recon/solve`` inside a
  ``stream/solve`` is never double-counted.
* **modeled** -- per-phase seconds from the same models the autotuner
  sums (:func:`modeled_phases`): ``hbm`` (bytes / bandwidth),
  ``dma_issue`` (issues x per-copy overhead -- the calibrated passport
  value when one is given, with its ``overhead_source`` provenance
  recorded in the report), ``exchange_ici`` / ``exchange_dci`` (wire
  bytes / link bandwidth), and their sum ``solve``.

The solve phase is measured directly and flagged when
``measured / modeled`` leaves ``[1/(1+threshold), 1+threshold]``.  One
host span cannot split device time into sub-phases, so the sub-rows
carry their modeled *share* of the measured solve
(``source="attributed"``): the breakdown Perfetto shows next to the
flag, not an independent measurement -- exactly the input a future
``autotune(measure=...)`` wall-clock re-ranking consumes.  ``load`` /
``upload`` have no model yet and are reported measured-only.

Doctest -- deterministic join under a fake clock and injected model:

>>> from .trace import Tracer
>>> t = Tracer(enabled=True, clock=iter([0.0, 2.0, 2.0, 2.5]).__next__)
>>> with t.span("stream/solve"):
...     pass
>>> with t.span("stream/load"):
...     pass
>>> rep = drift_report(t, modeled={"solve": 1.0, "hbm": 0.5,
...                                "dma_issue": 0.3, "exchange_ici": 0.2,
...                                "exchange_dci": 0.0}, threshold=0.5)
>>> solve = rep.row("solve")
>>> (solve.measured_s, solve.modeled_s, solve.ratio, solve.flagged)
(2.0, 1.0, 2.0, True)
>>> rep.row("dma_issue").measured_s  # 0.3 share of the measured 2.0 s
0.6
>>> rep.row("load").measured_s, rep.row("load").modeled_s
(0.5, None)
>>> [r.phase for r in rep.rows if r.flagged]
['solve']
"""
from __future__ import annotations

import dataclasses
import json

from .trace import Tracer

__all__ = [
    "PHASES",
    "SPAN_PHASE",
    "DriftRow",
    "DriftReport",
    "measured_phases",
    "modeled_phases",
    "drift_report",
]

# report rows, in render order: solve first (the directly measured
# total), its modeled decomposition next, the un-modeled staging rungs
# last
PHASES = (
    "solve", "hbm", "dma_issue", "exchange_ici", "exchange_dci",
    "load", "upload",
)

# span name -> phase (the taxonomy table in docs/observability.md)
SPAN_PHASE = {
    "stream/solve": "solve",
    "recon/solve": "solve",
    "serve/solve": "solve",
    "stream/load": "load",
    "serve/load": "load",
    "stream/stage": "upload",
    "stream/upload": "upload",
    "recon/stage": "upload",
}


@dataclasses.dataclass(frozen=True)
class DriftRow:
    """One phase of the modeled-vs-measured join."""

    phase: str
    measured_s: float | None
    modeled_s: float | None
    ratio: float | None  # measured / modeled (None when either missing)
    share: float | None  # modeled share of the solve (sub-phases only)
    source: str | None  # "span" | "attributed" | None (unmeasured)
    flagged: bool


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Per-phase drift rows + the provenance that priced the model."""

    rows: tuple
    threshold: float
    overhead_source: str
    per_copy_overhead_s: float

    def row(self, phase: str) -> DriftRow:
        for r in self.rows:
            if r.phase == phase:
                return r
        raise KeyError(phase)

    @property
    def flagged(self) -> list:
        return [r for r in self.rows if r.flagged]

    def render(self) -> str:
        """Human-readable table (what ``launch.recon --trace`` prints)."""
        def num(v):
            return "-" if v is None else f"{v:.4g}"

        lines = [
            f"drift report (threshold {self.threshold:g}, per-copy "
            f"overhead {self.per_copy_overhead_s:g}s "
            f"[{self.overhead_source}])",
            f"{'phase':<14}{'measured_s':>12}{'modeled_s':>12}"
            f"{'ratio':>9}  source",
        ]
        for r in self.rows:
            tag = "  DRIFT" if r.flagged else ""
            lines.append(
                f"{r.phase:<14}{num(r.measured_s):>12}"
                f"{num(r.modeled_s):>12}{num(r.ratio):>9}  "
                f"{r.source or '-'}{tag}"
            )
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps(
            {
                "threshold": self.threshold,
                "overhead_source": self.overhead_source,
                "per_copy_overhead_s": self.per_copy_overhead_s,
                "rows": [dataclasses.asdict(r) for r in self.rows],
            },
            indent=1,
            sort_keys=True,
        )


def measured_phases(spans) -> dict:
    """Sum span durations per phase (``Tracer`` or its event list).

    A span whose recorded ``parent`` maps to the same phase is skipped:
    nested same-phase spans (``recon/solve`` inside ``stream/solve``)
    count once, at the outermost level.  Spans carrying a truthy
    ``retry`` attr are skipped too: the models price one attempt per
    slab, so retried attempts (the resilience layer's ``retry=<n>``
    metadata, n >= 1) would inflate the measured side of the join.
    """
    events = spans.spans() if isinstance(spans, Tracer) else [
        e for e in spans if e.get("kind", "span") == "span"
    ]
    out: dict = {}
    for e in events:
        phase = SPAN_PHASE.get(e["name"])
        if phase is None:
            continue
        if SPAN_PHASE.get(e.get("parent")) == phase:
            continue  # same-phase child: already counted by its parent
        if e.get("attrs", {}).get("retry"):
            continue  # a retried attempt: the model prices one try
        out[phase] = out.get(phase, 0.0) + (e["t1"] - e["t0"])
    return out


def modeled_phases(
    rec,
    *,
    iters: int,
    n_slices: int,
    per_copy_overhead_s: float | None = None,
    passport=None,
) -> tuple[dict, dict]:
    """Per-phase modeled seconds of one CG solve on ``rec``'s plan.

    Uses the exact model stack the autotuner's modeled tier sums
    (``repro.tune.autotune.modeled_objective``): per fused minibatch,
    each operator moves ``spmm_traffic`` bytes over ``HW.hbm_bw`` and
    issues ``dma_issues`` copies at the per-copy overhead (the
    passport's calibrated value when given), and each reduction moves
    ``comm_volume`` bytes over the link-class bandwidths.  CGNR applies
    each operator ``iters + 1`` times (one ``A``/``A^T`` pair per
    iteration plus the initial residual/normal pair -- see
    ``core.solver.cgnr``).

    Returns ``(phases, meta)``: phase -> seconds (``solve`` is the sum
    of the four sub-phases) and the overhead provenance.
    """
    from ..kernels.traffic import (
        PER_COPY_OVERHEAD_S,
        op_segments_per_stage,
        spmm_traffic,
    )
    from ..launch.hlo_analysis import HW
    from ..launch.xct_perf import comm_volume

    overhead = per_copy_overhead_s
    source = "default" if overhead is None else "measured"
    if passport is not None and overhead is None:
        overhead = getattr(passport, "per_copy_overhead_s", None)
        source = getattr(passport, "overhead_source", "default")
    if overhead is None:
        overhead = PER_COPY_OVERHEAD_S
        source = "default"

    cfg, pol, plan = rec.cfg, rec.policy, rec.plan
    granule = rec.n_batch * cfg.fuse
    if n_slices % granule:
        raise ValueError(
            f"n_slices={n_slices} not a multiple of the solve granule "
            f"{granule}"
        )
    minis = n_slices // granule  # fused minibatches per application
    apps = iters + 1  # operator applications per CG solve (per op)

    issue_s = hbm_s = 0.0
    for op in (plan.proj, plan.back):
        _, b, s, r, k = op.inds.shape
        t = spmm_traffic(
            b, s, r, k, op.winmap.shape[-1], cfg.fuse,
            storage_bytes=pol.storage_bytes,
            vals_bytes=pol.vals_bytes,
            staging=cfg.staging,
            dma=cfg.dma,
            segments_per_stage=op_segments_per_stage(op),
        )
        issue_s += t["dma_issues"] * overhead * minis * apps
        hbm_s += t["hbm_bytes"] / HW.hbm_bw * minis * apps
    wire = comm_volume(
        plan, cfg.comm_mode, cfg.fuse, pol.comm_bytes, rec.topology,
        wire=cfg.wire,
    )
    ici_s = wire["ici"] / HW.ici_bw * minis * apps
    dci_s = wire["dci"] / HW.dci_bw * minis * apps
    phases = {
        "hbm": hbm_s,
        "dma_issue": issue_s,
        "exchange_ici": ici_s,
        "exchange_dci": dci_s,
    }
    phases["solve"] = sum(phases.values())
    return phases, {
        "overhead_source": source,
        "per_copy_overhead_s": float(overhead),
    }


def drift_report(
    spans,
    *,
    rec=None,
    iters: int | None = None,
    n_slices: int | None = None,
    modeled: dict | None = None,
    threshold: float = 0.5,
    per_copy_overhead_s: float | None = None,
    passport=None,
) -> DriftReport:
    """Join measured span totals against modeled phase predictions.

    Args:
      spans: a :class:`~repro.obs.trace.Tracer` or its event list.
      rec / iters / n_slices: price the model from a live
        ``Reconstructor`` (:func:`modeled_phases`).
      modeled: inject the phase model directly (``{"solve": s, ...}``;
        sub-phases optional) -- tests and doctests use this for
        determinism; overrides ``rec``.
      threshold: flag a *directly measured* phase when
        ``measured / modeled`` falls outside
        ``[1/(1+threshold), 1+threshold]``.
      per_copy_overhead_s / passport: overhead provenance for the
        model (see :func:`modeled_phases`).
    """
    meta = {"overhead_source": "injected", "per_copy_overhead_s": 0.0}
    if modeled is None:
        if rec is None or iters is None or n_slices is None:
            raise ValueError(
                "pass either modeled= or all of rec=/iters=/n_slices="
            )
        modeled, meta = modeled_phases(
            rec, iters=iters, n_slices=n_slices,
            per_copy_overhead_s=per_copy_overhead_s, passport=passport,
        )
    measured = measured_phases(spans)
    solve_modeled = modeled.get("solve")
    solve_measured = measured.get("solve")

    rows: list[DriftRow] = []
    for phase in PHASES:
        mod = modeled.get(phase)
        if phase in ("load", "upload"):
            mod = modeled.get(phase)  # measured-only unless injected
            mea = measured.get(phase)
            src = "span" if mea is not None else None
        elif phase == "solve":
            mea, src = solve_measured, (
                "span" if solve_measured is not None else None
            )
        else:
            # attributed: modeled share of the measured solve total
            if (
                mod is None or solve_modeled in (None, 0.0)
                or solve_measured is None
            ):
                mea, src = None, None
            else:
                mea = solve_measured * (mod / solve_modeled)
                src = "attributed"
        ratio = (
            mea / mod
            if mea is not None and mod not in (None, 0.0)
            else None
        )
        share = (
            mod / solve_modeled
            if phase not in ("solve", "load", "upload")
            and mod is not None and solve_modeled not in (None, 0.0)
            else None
        )
        flagged = bool(
            src == "span"
            and ratio is not None
            and not (1.0 / (1.0 + threshold) <= ratio <= 1.0 + threshold)
        )
        rows.append(
            DriftRow(
                phase=phase, measured_s=mea, modeled_s=mod,
                ratio=ratio, share=share, source=src, flagged=flagged,
            )
        )
    return DriftReport(
        rows=tuple(rows),
        threshold=float(threshold),
        overhead_source=meta["overhead_source"],
        per_copy_overhead_s=meta["per_copy_overhead_s"],
    )
