"""Minimal deterministic stand-in for the ``hypothesis`` library.

The test environment pins no ``hypothesis`` wheel, but the property tests
only use a narrow slice of its API: ``@given`` over ``st.integers``,
``st.floats`` and ``st.sampled_from``, throttled by ``@settings``.  This
module implements that slice as a *deterministic* example sweep (seeded
draws + range endpoints), which keeps the properties exercised and the
suite reproducible.

If a real ``hypothesis`` distribution is importable from anywhere else on
``sys.path`` (e.g. CI installs it), this module steps aside and re-exports
the real thing, so installing hypothesis transparently upgrades the tests
to true property-based search.
"""
from __future__ import annotations

import functools
import importlib.util
import math
import os
import sys

# --------------------------------------------------------------------- #
# defer to a real installation when one exists
# --------------------------------------------------------------------- #


def _find_real():
    here = os.path.dirname(os.path.abspath(__file__))
    paths = [
        p for p in sys.path
        if os.path.abspath(p or os.getcwd()) != here
    ]
    try:
        from importlib.machinery import PathFinder

        return PathFinder.find_spec("hypothesis", paths)
    except Exception:  # pragma: no cover - defensive
        return None


_real_spec = _find_real()
if _real_spec is not None and _real_spec.submodule_search_locations:
    _mod = importlib.util.module_from_spec(_real_spec)
    sys.modules[__name__] = _mod
    _real_spec.loader.exec_module(_mod)
else:
    # ----------------------------------------------------------------- #
    # the shim proper
    # ----------------------------------------------------------------- #
    class _Strategy:
        """Deterministic example generator: fixed must-cover values first
        (range endpoints / every member), then seeded random draws.

        Strategies are stateless, so one module-level strategy object can
        back any number of ``@given`` tests.
        """

        def __init__(self, cover, draw):
            self._cover = tuple(cover)
            self._draw = draw  # (rng) -> value

        def examples(self, n: int, rng):
            out = list(self._cover[:n])
            out.extend(self._draw(rng) for _ in range(n - len(out)))
            return out

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**31) if min_value is None else int(min_value)
            hi = 2**31 - 1 if max_value is None else int(max_value)
            return _Strategy(
                (lo, hi) if hi != lo else (lo,),
                lambda rng: int(rng.integers(lo, hi + 1)),
            )

        @staticmethod
        def floats(min_value=None, max_value=None, **_kw):
            lo = -1e30 if min_value is None else float(min_value)
            hi = 1e30 if max_value is None else float(max_value)

            def draw(rng):
                if lo > 0 and hi / max(lo, 1e-300) > 1e3:
                    # wide positive range: log-uniform, matching the real
                    # library's bias toward varied magnitudes
                    return math.exp(rng.uniform(math.log(lo), math.log(hi)))
                return float(rng.uniform(lo, hi))

            return _Strategy((lo, hi), draw)

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(
                elems, lambda rng: elems[int(rng.integers(len(elems)))]
            )

    st = strategies

    def settings(max_examples: int = 20, deadline=None, **_kw):
        """Attach the example budget to an (already-@given-wrapped) test."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                import zlib

                import numpy as np

                n = getattr(wrapper, "_shim_max_examples", 20)
                # crc32, not hash(): str hashing is salted per process
                columns = [
                    s.examples(n, np.random.default_rng(
                        zlib.crc32(f"{fn.__name__}:{i}".encode())
                    ))
                    for i, s in enumerate(strats)
                ]
                for row in zip(*columns):
                    fn(*args, *row, **kwargs)

            # Strategy args are filled here, not by pytest: hide the
            # inner signature so they are not mistaken for fixtures.
            import inspect

            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco
