"""Shared benchmark utilities.

``emit`` prints the human-readable CSV row *and* accumulates a
machine-readable record per suite (the leading ``name`` path component),
flushed to ``BENCH_<suite>.json`` in the working directory after every
row -- so a partially failed run still leaves the rows it measured.
``k=v`` tokens in the derived string are parsed into typed fields, which
is what lets CI track the perf trajectory across commits.
"""
from __future__ import annotations

import json
import re
import time

import numpy as np

_RECORDS: dict[str, list] = {}

_NUM_RE = re.compile(r"^-?\d+(\.\d+)?([eE][+-]?\d+)?")


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds per call (after jit warmup)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _parse_derived(derived: str) -> dict:
    """``"speedup=1.61x ai=0.23flop/B"`` -> numeric fields (unit tails
    stripped); non-numeric values kept as strings."""
    out = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        key, val = tok.split("=", 1)
        m = _NUM_RE.match(val)
        out[key] = float(m.group(0)) if m else val
    return out


def reset(suite: str | None = None):
    """Drop accumulated rows (one suite, or all).  Call before re-running
    a bench in the same process, or BENCH_<suite>.json grows duplicate
    rows; ``benchmarks.run.main`` does this once per invocation."""
    if suite is None:
        _RECORDS.clear()
    else:
        _RECORDS.pop(suite, None)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    suite = name.split("/", 1)[0]
    rec = {"name": name, "us_per_call": round(float(us_per_call), 3),
           "derived": derived}
    rec.update(_parse_derived(derived))
    rows = _RECORDS.setdefault(suite, [])
    rows.append(rec)
    with open(f"BENCH_{suite}.json", "w") as f:
        json.dump(rows, f, indent=1)
