"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds per call (after jit warmup)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
