"""Paper Table IV + Fig. 11: communicated data volumes per hierarchy level.

Computed exactly from the partition plan's footprints (no wall time --
the paper's Table IV is a volume table).  Levels map Summit -> TPU:
socket -> minor ICI axis, node -> major ICI axis, global -> inter-pod.

Per-level volumes come from the same ``dist.CommPlan`` the runtime
executes -- one model for benchmarks, roofline sweeps and collectives
(all five modes; the sparse capacities come from the exact exchange
tables via ``core.partition.exchange_volume_params``):

  direct       every device sends its full dense partial row space
  hier         reduce-scatter ladder: level L carries volume / prod(faster)
  sparse       footprint-compressed exchange (beyond-paper): only rows
               that carry partial sums travel
  hier-sparse  the two tricks composed: socket-level dedup of the
               overlapping footprints, then a sparse exchange across the
               slow link only
  hier-sparse-q8  ... plus int8 wire compression of the slow-axis
               all-to-all (1 B/row + per-(peer, slice) f32 inv-scale
               instead of the f16 wire)

Derived: slow-link traffic reduction vs direct (the paper reports 58-64%).
"""
from __future__ import annotations

import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import (
    PartitionConfig, build_plan, exchange_volume_params,
)
from repro.dist import Topology

from .common import emit


def run(n: int = 64, p_data: int = 16, fuse: int = 16,
        quick: bool = False):
    if quick:
        n, p_data = 48, 8
    geo = XCTGeometry(n=n, n_angles=n // 2)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=p_data, tile=8, rows_per_block=16,
                        nnz_per_stage=16),
        a=a,
    )
    # hierarchy fan-out: fast x slow levels exactly factoring p_data
    # (largest divisor <= sqrt, so topo.n_data == p_data and the sparse
    # peer count matches the real exchange group); the slow level is the
    # benchmark's "global" (DCI) rung, per the Summit -> TPU mapping
    fast = max(
        d for d in range(1, int(np.sqrt(p_data)) + 1) if p_data % d == 0
    )
    slow = p_data // fast
    topo = Topology.from_sizes(
        [("model", fast, "ici"), ("data", slow, "dci")]
    )
    comm_b = 2  # half-precision wire (paper Sec. III-C)
    for name, op in (("proj", plan.proj), ("back", plan.back)):
        rows = op.n_rows_pad
        dense = rows * fuse * comm_b  # per-device dense partial
        params = exchange_volume_params(op, topo)
        foot = float(np.mean([r.size for r in op.foot_rows]))
        by_link = {
            mode: topo.plan(mode, **params).wire_bytes_by_link(dense)
            for mode in ("direct", "hier", "sparse", "hier-sparse")
        }
        by_link["hier-sparse-q8"] = topo.plan(
            "hier-sparse", wire="q8", **params
        ).wire_bytes_by_link(dense)
        # direct: full partial crosses the slowest level
        direct_slow = by_link["direct"]["dci"]
        hier_fast, hier_slow = by_link["hier"]["ici"], by_link["hier"]["dci"]
        sparse_slow = by_link["sparse"]["dci"]
        hs_fast, hs_slow = (
            by_link["hier-sparse"]["ici"], by_link["hier-sparse"]["dci"]
        )
        emit(
            f"comm_volumes/{name}/direct", 0.0,
            f"slow_link={direct_slow/2**20:.2f}MiB/dev",
        )
        emit(
            f"comm_volumes/{name}/hier", 0.0,
            f"fast={hier_fast/2**20:.2f}MiB slow={hier_slow/2**20:.2f}"
            f"MiB reduction={(1-hier_slow/direct_slow)*100:.0f}%",
        )
        emit(
            f"comm_volumes/{name}/sparse", 0.0,
            f"slow={sparse_slow/2**20:.2f}MiB/dev "
            f"foot_frac={foot/rows:.3f} "
            f"reduction={(1-min(1,sparse_slow/direct_slow))*100:.0f}%",
        )
        emit(
            f"comm_volumes/{name}/hier-sparse", 0.0,
            f"fast={hs_fast/2**20:.2f}MiB slow={hs_slow/2**20:.2f}MiB "
            f"dedup_vs_sparse={(1-hs_slow/max(sparse_slow,1e-12))*100:.0f}%"
            f" reduction={(1-min(1,hs_slow/direct_slow))*100:.0f}%"
            f" comm_bytes={hs_fast + hs_slow:.0f}",
        )
        # compressed wire (ISSUE 8): the slow-axis all-to-all ships int8
        # + one f32 inv-scale per (slow peer, slice) instead of the f16
        # wire -- ~halves the slow hop; the accumulating fast rung stays
        # native.  comm_bytes (total wire per device) is CI-gated
        # downward so the compression win cannot silently regress.
        q8_fast, q8_slow = (
            by_link["hier-sparse-q8"]["ici"],
            by_link["hier-sparse-q8"]["dci"],
        )
        emit(
            f"comm_volumes/{name}/hier-sparse-q8", 0.0,
            f"fast={q8_fast/2**20:.2f}MiB slow={q8_slow/2**20:.2f}MiB "
            f"vs_f16_slow={(1-q8_slow/max(hs_slow,1e-12))*100:.0f}% "
            f"reduction={(1-min(1,q8_slow/direct_slow))*100:.0f}%"
            f" comm_bytes={q8_fast + q8_slow:.0f}",
        )


if __name__ == "__main__":
    run()
