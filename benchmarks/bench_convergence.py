"""Paper Fig. 13: convergence vs precision on noisy (Chip-like) data.

Runs in a subprocess with JAX_ENABLE_X64=1 so the "double" policy is a
real f64 baseline.  Derived: relative residual after the fixed iteration
budget per precision -- the paper's claim is that half/mixed track
double/single because the numerical noise floor sits below measurement
noise.
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import emit

_SCRIPT = """
import numpy as np, jax
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices, simulate_measurements
n, iters = {n}, {iters}
geo = XCTGeometry(n=n, n_angles=n)
a = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=1, tile=8,
                  rows_per_block=16, nnz_per_stage=16), a=a)
x_true = phantom_slices(n, 2)
sino = simulate_measurements(a, x_true, noise=0.02, seed=1)
for prec in ("double", "single", "half", "mixed"):
    rec = Reconstructor(plan,
        cfg=ReconConfig(precision=prec, comm_mode="rs", fuse=2))
    import time
    t0 = time.perf_counter()
    x, res = rec.reconstruct(sino, iters=iters)
    dt = time.perf_counter() - t0
    rel = res[-1, 0] / res[0, 0]
    err = float(np.linalg.norm(x - x_true) / np.linalg.norm(x_true))
    print(f"ROW {{prec}} {{dt:.3f}} {{rel:.6f}} {{err:.4f}}")
"""


def run(n: int = 48, iters: int = 16, quick: bool = False):
    if quick:
        n, iters = 32, 8
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(n=n, iters=iters)],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-800:])
    for line in r.stdout.splitlines():
        if line.startswith("ROW"):
            _, prec, dt, rel, err = line.split()
            emit(
                f"convergence/{prec}", float(dt) * 1e6,
                f"rel_residual={rel} recon_err={err} iters={iters}",
            )


if __name__ == "__main__":
    run()
