"""Out-of-core streaming throughput: slices/s vs slab size x overlap.

One row per (Y_slab, pipeline mode) cell, sweeping the staging ladder
A/B: ``sync`` (no prefetch, upload on the critical path), ``overlap``
(disk -> host prefetch only, upload still synchronous) and
``overlap_dev`` (prefetch + device-upload double-buffering: slab
``i+1``'s ``jax.device_put`` runs in the prefetch thread while slab
``i`` solves -- the default production schedule).  The whole sinogram
lives in an on-disk ``repro.stream.SlabStore``; the drain runs
budget-shaped slabs through the solver.  Derived fields carry slices/s,
the modeled per-slab HBM traffic and arithmetic intensity from
``stream.scheduler.suggest_slab`` (same ``kernels.traffic`` formula the
roofline sweeps use), and the measured per-slab load/upload/solve split
-- ``upload_hidden=1`` marks rows whose uploads ran off the critical
path, so the JSON artifact shows upload time hidden under solve time in
the overlapped mode.  Emits ``BENCH_stream.json`` via
``benchmarks.common.emit`` (CI's bench-smoke job uploads it and
``tools/bench_check.py`` guards it against regressions).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import os

import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.stream import SlabStore, reconstruct_streaming, simulate_to_store
from repro.stream.scheduler import SlabPlan, suggest_slab  # noqa: F401

from .common import emit

# tag -> (overlap, device_upload)
MODES = {
    "sync": (False, "sync"),
    "overlap": (True, "sync"),
    "overlap_dev": (True, "overlap"),
}


def run(n: int = 48, iters: int = 6, quick: bool = False,
        ab: bool = True, trace: bool = False):
    if trace:
        obs_trace.enable()
    if quick:
        n, iters = 32, 4
    y_total = 8 if quick else 16
    geo = XCTGeometry(n=n, n_angles=max(16, n // 2))
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=1, tile=8, rows_per_block=16,
                        nnz_per_stage=16),
        a=a,
    )
    cfg = ReconConfig(precision="mixed", comm_mode="hier", fuse=2)
    rec = Reconstructor(plan, cfg=cfg)
    granule = rec.n_batch * cfg.fuse
    workdir = tempfile.mkdtemp(prefix="bench_stream_")
    modes = MODES if ab else {"overlap_dev": MODES["overlap_dev"]}
    try:
        sino = SlabStore.create(
            os.path.join(workdir, "sino"), geo.n_rays, y_total, granule
        )
        simulate_to_store(a, n, sino, noise=0.0, seed=0)
        slabs = sorted({granule, y_total // 2, y_total})
        for y_slab in slabs:
            for tag, (overlap, upload) in modes.items():
                out = os.path.join(workdir, f"vol_{y_slab}_{tag}")
                # rep 0 is warmup (compiles the slab shape), not timed
                ts = []
                res = None
                for rep in range(2 if quick else 3):
                    shutil.rmtree(out, ignore_errors=True)
                    t0 = time.perf_counter()
                    res = reconstruct_streaming(
                        rec, sino, out, iters=iters, y_slab=y_slab,
                        overlap=overlap, device_upload=upload,
                    )
                    if rep:
                        ts.append(time.perf_counter() - t0)
                t = min(ts)
                sp = suggest_slab(
                    plan, cfg, rec.topology,
                    # large budget: we only want the traffic model of
                    # this slab size, not a re-size
                    1 << 40, n_slices=y_slab, overlap=overlap,
                )
                ai = sp.slab_flops / max(sp.slab_hbm_bytes, 1.0)
                up_s = float(np.mean(res.upload_s))
                solve_s = float(np.mean(res.solve_s))
                load_s = float(np.mean(res.load_s))
                # legacy *_ms fields kept one release alongside *_s
                up_ms, solve_ms, load_ms = (
                    1e3 * up_s, 1e3 * solve_s, 1e3 * load_s
                )
                emit(
                    f"stream/slab{y_slab}/{tag}",
                    t * 1e6,
                    f"slices_per_s={y_total / t:.2f} y_slab={y_slab} "
                    f"slabs={-(-y_total // y_slab)} iters={iters} "
                    f"ai={ai:.3f}flop/B "
                    f"slab_hbm_mb={sp.slab_hbm_bytes / 2**20:.1f} "
                    f"load_s={load_s:.4f} upload_s={up_s:.4f} "
                    f"solve_s={solve_s:.4f} "
                    f"load_ms={load_ms:.1f} upload_ms={up_ms:.1f} "
                    f"solve_ms={solve_ms:.1f} "
                    f"upload_hidden={int(res.upload_overlapped)}",
                )
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    if trace:
        obs_export.write_chrome_trace("TRACE_stream.json")
        print("trace written to TRACE_stream.json")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--no-ab", dest="ab", action="store_false",
        help="run only the production overlap_dev schedule",
    )
    ap.add_argument(
        "--trace", action="store_true",
        help="record repro.obs spans; writes TRACE_stream.json",
    )
    args = ap.parse_args()
    run(quick=args.quick, ab=args.ab, trace=args.trace)
