"""Paper Fig. 9: XCT-optimized SpMM speedup + roofline vs fusing factor.

Sweeps the minibatch (slice-fusing) size F across precision policies on a
real blocked-ELL shard -- the ladder now runs down to the quantized
``q8`` rung (int8 vals + per-block power-of-two scales dequantized
inline; rows carry the measured resident ``hbm_bytes`` of the shard at
each width, which the CI gate guards downward) -- for the staging x DMA
A/B ladder: ``fused`` (the
kernel streams each stage's window HBM -> VMEM itself with run-length
*coalesced* copies -- the production path), ``fused-perrow`` (same
kernel, one copy per window row -- the DMA-issue baseline the coalescing
refactor beats) and the legacy ``gather`` baseline (XLA gather
materializes the window tensor in HBM first -- one extra full pass over
the staged data).  CPU wall time measures the *relative* effect of
fusing (operator elements amortized over F slices -- the paper's
register reuse); the derived column reports arithmetic intensity, the
projected TPU-roofline GFLOP/s per chip, and the modeled DMA issue
count, all straight from the shared traffic model
``repro.kernels.traffic.spmm_traffic``.  The fused rows also carry the
*measured* segments-per-stage statistics of the shard's real winmap
(``ops.winmap_segments``): mean segments per stage and the copy-length
histogram, so the JSON artifact records how long the Hilbert runs
actually are.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.precision import quantize_block_vals
from repro.kernels.ops import (
    apply_operator,
    dma_issue_count,
    segment_histogram,
    sort_segments_by_class,
    winmap_segments,
)
from repro.kernels.traffic import spmm_traffic
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.obs.trace import span as obs_span

from .common import emit, timeit

PEAK = 197e12
HBM = 819e9


def _seg_stats(op):
    """Measured DMA statistics of a shard's winmap run-length tables.

    Returns ``(per_stage_mean, mean_len, issues, hist_tok)``:
    segments-per-stage mean (what the traffic model consumes), mean
    copy LENGTH in winmap entries per issued copy (the ``segs_mean``
    column the CI gate guards upward -- longer runs = better
    coalescing), the total issue count of device 0's shard
    (``dma_issues``, guarded downward), and the length histogram.
    """
    segs = op.winsegs[0]  # [B, S, NSEG, 3] of device 0
    per_stage = (segs[..., 2] > 0).sum(axis=-1)  # [B, S]
    issues = dma_issue_count(segs)
    mean_len = op.winmap[0].size / max(issues, 1)
    hist = segment_histogram(segs)
    # leading "L" keeps benchmarks.common._parse_derived from mangling
    # the token into a float
    hist_tok = "|".join(
        f"L{ln}:{ct}" for ln, ct in sorted(hist.items())
    )
    return float(per_stage.mean()), float(mean_len), issues, hist_tok


def calibrate_per_copy_overhead(
    buf: int = 256, b: int = 4, s: int = 2, r: int = 32, k: int = 32,
    f: int = 8, reps: int = 3,
):
    """Measure PER_COPY_OVERHEAD_S with a controlled micro-sweep.

    Two synthetic winmaps with IDENTICAL shape and byte volume but
    opposite run structure drive the same fused kernel: ``contig``
    (arange -> a handful of power-of-two runs) vs ``strided``
    (lo/hi interleave -> every run is length 1, ~BUF issues per
    window).  Same bytes moved, so the wall-clock delta divided by the
    issue-count delta isolates the fixed cost of issuing one copy:

        per_copy_overhead = (t_hi - t_lo) / (issues_hi - issues_lo)

    On a real accelerator this calibrates the DMA-engine dispatch cost
    the traffic model's constant stands in for; under Pallas interpret
    mode (any CPU run) the copies are emulated element loops, so the
    number is an *emulator* artifact -- it is still returned (the
    calibration plumbing is exercised end to end, and the autotuner's
    passport records it) but tagged ``overhead_source=
    "measured-interpret"``, and the traffic model is told timings were
    taken under interpret so it can warn against ranking dma modes on
    them (``spmm_traffic(..., interpret_timed=True)``).

    Returns a dict with ``per_copy_overhead_s``, ``overhead_source``,
    and the raw sweep points.
    """
    import jax

    rng = np.random.default_rng(0)
    inds = jnp.asarray(
        rng.integers(0, buf, size=(b, s, r, k)).astype(np.int16)
    )
    vals = jnp.asarray(
        rng.random(size=(b, s, r, k)).astype(np.float16)
    )
    x = jnp.asarray(rng.normal(size=(buf, f)).astype(np.float32))
    contig = np.broadcast_to(
        np.arange(buf, dtype=np.int32), (b, s, buf)
    ).copy()
    half = buf // 2
    strided = np.empty(buf, np.int32)
    strided[0::2] = np.arange(half, dtype=np.int32)
    strided[1::2] = half + np.arange(buf - half, dtype=np.int32)
    strided = np.broadcast_to(strided, (b, s, buf)).copy()
    pts = {}
    for tag, wm in (("contig", contig), ("strided", strided)):
        segs, off = sort_segments_by_class(winmap_segments(wm), buf)
        fn = jax.jit(
            lambda xx, i=inds, v=vals, w=jnp.asarray(wm),
            sg=jnp.asarray(segs), so=jnp.asarray(off):
            apply_operator(i, v, w, xx, staging="fused",
                           dma="coalesced", winsegs=sg, segoff=so)
        )
        pts[tag] = {
            "issues": dma_issue_count(segs),
            "seconds": timeit(fn, x, reps=reps),
        }
    d_issues = pts["strided"]["issues"] - pts["contig"]["issues"]
    d_t = pts["strided"]["seconds"] - pts["contig"]["seconds"]
    overhead = max(d_t, 0.0) / max(d_issues, 1)
    interpret = jax.default_backend() not in ("tpu", "gpu")
    if interpret:
        # fires the shared model's interpret-timing warning exactly
        # once per calibration: these seconds must not rank dma modes
        spmm_traffic(b, s, r, k, buf, f, interpret_timed=True)
    return {
        "per_copy_overhead_s": float(overhead),
        "overhead_source": (
            "measured-interpret" if interpret else "measured"
        ),
        **{f"{t}_{m}": pts[t][m] for t in pts for m in pts[t]},
    }


def run(n: int = 64, fusings=(1, 2, 4, 8, 16, 32), quick: bool = False,
        ab: bool = True, trace: bool = False):
    if trace:
        obs_trace.enable()
    geo = XCTGeometry(n=n, n_angles=n // 2)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=1, tile=8, rows_per_block=32,
                        nnz_per_stage=32),
        a=a,
    )
    op = plan.proj
    inds = jnp.asarray(op.inds[0])
    vals = jnp.asarray(op.vals[0])
    winmap = jnp.asarray(op.winmap[0])
    winsegs = jnp.asarray(op.winsegs[0])
    segoff = jnp.asarray(op.segoff[0])
    q_vals, q_scales = quantize_block_vals(vals, jnp.int8)
    segs_stage, segs_mean, _, segs_hist = _seg_stats(op)
    _, b, s, r, k = op.inds.shape
    buf = op.winmap.shape[-1]
    rng = np.random.default_rng(0)
    if quick:
        fusings = tuple(fusings)[:3]
    base_t = None
    # the quantized rung: int8 vals + per-block scales through the same
    # kernel (scales ride scalar prefetch); vectors stay f16
    policies = (
        [("single", jnp.float32), ("mixed", jnp.float16),
         ("q8", jnp.float16)]
        if quick
        else [
            ("double", jnp.float32),  # f64 n/a on TPU; f32 stands in
            ("single", jnp.float32),
            ("half", jnp.float16),
            ("mixed", jnp.float16),
            ("q8", jnp.float16),
        ]
    )
    # the A/B ladder: (row tag, staging, dma)
    paths = [("fused", "fused", "coalesced")]
    if ab:
        paths += [
            ("fused-perrow", "fused", "per_row"),
            ("gather", "gather", "coalesced"),
        ]
    for prec, sdt in policies:
        cdt = jnp.float16 if prec == "half" else jnp.float32
        quant = prec == "q8"
        v_run = q_vals if quant else vals
        sc_run = q_scales if quant else None
        vb = 1 if quant else jnp.dtype(sdt).itemsize
        # measured resident footprint of the real shard at this width
        # (value stream + scale table for the quantized rung)
        op_hbm = op.hbm_bytes(value_bytes=vb)
        for f in fusings:
            x = jnp.asarray(
                rng.normal(size=(op.cols_per_dev, f)).astype(np.float32)
            )
            for tag, staging, dma in paths:
                if quant and staging != "fused":
                    continue  # gather baseline dequantizes eagerly
                fn = jax.jit(
                    lambda xx, i=inds, v=v_run, w=winmap, sg=winsegs,
                    so=segoff, sd=sdt, cd=cdt, st=staging, dm=dma,
                    sc=sc_run:
                    apply_operator(i, v, w, xx, storage_dtype=sd,
                                   compute_dtype=cd, staging=st,
                                   dma=dm, winsegs=sg, segoff=so,
                                   scales=sc)
                )
                # the span wraps the timed cell, never the kernel
                # inner loop: with tracing off this is two clock reads
                # per CELL (the no-overhead acceptance)
                with obs_span(
                    f"spmm/{prec}/{tag}", f=f
                ):
                    t = timeit(fn, x, reps=3 if not quick else 1)
                tr = spmm_traffic(
                    b, s, r, k, buf, f,
                    storage_bytes=jnp.dtype(sdt).itemsize,
                    vals_bytes=vb,
                    staging=staging, dma=dma,
                    segments_per_stage=segs_stage,
                )
                flops = tr["flops"]
                if base_t is None:
                    base_t = t / flops  # s/flop at the F=1 baseline
                ai = tr["intensity"]
                tpu_gflops = min(PEAK, ai * HBM) / 1e9
                extra = ""
                if staging == "fused":
                    extra = (
                        f" dma_issues={tr['dma_issues']:.0f}"
                        f" segs_mean={segs_mean:.1f}"
                        f" seg_hist={segs_hist}"
                    )
                emit(
                    f"spmm_fusing/{prec}/{tag}/F={f}",
                    t * 1e6,
                    # throughput speedup per unit work (Fig. 9a metric)
                    f"speedup={base_t / (t / flops):.2f}x "
                    f"ai={ai:.2f}flop/B "
                    f"hbm_bytes={op_hbm} "
                    f"roofline={tpu_gflops:.0f}GF/s" + extra,
                )
    if trace:
        obs_export.write_chrome_trace("TRACE_spmm_fusing.json")
        print("trace written to TRACE_spmm_fusing.json")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--no-ab", dest="ab", action="store_false",
        help="skip the per-row / gather baseline arms",
    )
    ap.add_argument(
        "--trace", action="store_true",
        help="record repro.obs spans; writes TRACE_spmm_fusing.json",
    )
    args = ap.parse_args()
    run(quick=args.quick, ab=args.ab, trace=args.trace)
