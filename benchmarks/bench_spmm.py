"""Paper Fig. 9: XCT-optimized SpMM speedup + roofline vs fusing factor.

Sweeps the minibatch (slice-fusing) size F across precision policies on a
real blocked-ELL shard, for both staging paths: ``fused`` (the kernel
streams each stage's window HBM -> VMEM itself, paper Listing 1) and the
legacy ``gather`` baseline (XLA gather materializes the window tensor in
HBM first -- one extra full pass over the staged data).  CPU wall time
measures the *relative* effect of fusing (operator elements amortized
over F slices -- the paper's register reuse); the derived column reports
arithmetic intensity and the projected TPU-roofline GFLOP/s per chip
(min of compute and memory-bound bounds), both straight from the shared
traffic model ``repro.kernels.traffic.spmm_traffic`` -- the fused rows
show the staging HBM term eliminated (strictly higher AI at every F).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.kernels.ops import apply_operator
from repro.kernels.traffic import spmm_traffic

from .common import emit, timeit

PEAK = 197e12
HBM = 819e9


def run(n: int = 64, fusings=(1, 2, 4, 8, 16, 32), quick: bool = False):
    geo = XCTGeometry(n=n, n_angles=n // 2)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=1, tile=8, rows_per_block=32,
                        nnz_per_stage=32),
        a=a,
    )
    op = plan.proj
    inds = jnp.asarray(op.inds[0])
    vals = jnp.asarray(op.vals[0])
    winmap = jnp.asarray(op.winmap[0])
    _, b, s, r, k = op.inds.shape
    buf = op.winmap.shape[-1]
    rng = np.random.default_rng(0)
    if quick:
        fusings = tuple(fusings)[:3]
    base_t = None
    policies = (
        [("single", jnp.float32), ("mixed", jnp.float16)]
        if quick
        else [
            ("double", jnp.float32),  # f64 n/a on TPU; f32 stands in
            ("single", jnp.float32),
            ("half", jnp.float16),
            ("mixed", jnp.float16),
        ]
    )
    for prec, sdt in policies:
        cdt = jnp.float16 if prec == "half" else jnp.float32
        for f in fusings:
            x = jnp.asarray(
                rng.normal(size=(op.cols_per_dev, f)).astype(np.float32)
            )
            for staging in ("fused", "gather"):
                fn = jax.jit(
                    lambda xx, i=inds, v=vals, w=winmap, sd=sdt,
                    cd=cdt, st=staging:
                    apply_operator(i, v, w, xx, storage_dtype=sd,
                                   compute_dtype=cd, staging=st)
                )
                t = timeit(fn, x, reps=3 if not quick else 1)
                tr = spmm_traffic(
                    b, s, r, k, buf, f,
                    storage_bytes=jnp.dtype(sdt).itemsize,
                    staging=staging,
                )
                flops = tr["flops"]
                if base_t is None:
                    base_t = t / flops  # s/flop at the F=1 baseline
                ai = tr["intensity"]
                tpu_gflops = min(PEAK, ai * HBM) / 1e9
                emit(
                    f"spmm_fusing/{prec}/{staging}/F={f}",
                    t * 1e6,
                    # throughput speedup per unit work (Fig. 9a metric)
                    f"speedup={base_t / (t / flops):.2f}x "
                    f"ai={ai:.2f}flop/B "
                    f"roofline={tpu_gflops:.0f}GF/s",
                )


if __name__ == "__main__":
    run()
