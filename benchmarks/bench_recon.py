"""Paper Table III: end-to-end reconstruction speedup by optimization
level x precision.

Levels mirror the paper's rows:
  part      partitioning only: fuse=1 (no slice fusing), direct comm,
            no overlap (the "Part. Opt." baseline)
  kernel    + optimized SpMM: fused minibatches (F=4 here)
  comm      + hierarchical communication + pipeline overlap

CPU wall time; speedups are the derived quantity (the paper reports
23.19x for Shale with all three levels + mixed precision).
"""
from __future__ import annotations

import numpy as np

from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
from repro.data.phantom import phantom_slices

from .common import emit, timeit

LEVELS = {
    "part": dict(fuse=1, comm_mode="direct", overlap=False),
    "kernel": dict(fuse=4, comm_mode="direct", overlap=False),
    "comm": dict(fuse=4, comm_mode="hier", overlap=True),
}


def run(n: int = 48, iters: int = 8, quick: bool = False):
    geo = XCTGeometry(n=n, n_angles=n // 2)
    a = build_system_matrix(geo)
    plan = build_plan(
        geo,
        PartitionConfig(n_data=1, tile=8, rows_per_block=16,
                        nnz_per_stage=16),
        a=a,
    )
    x_true = phantom_slices(n, 4)
    sino = (a @ x_true).astype(np.float32)
    precisions = ["mixed"] if quick else ["single", "half", "mixed"]
    base = None
    for level, kw in LEVELS.items():
        for prec in precisions:
            rec = Reconstructor(
                plan, cfg=ReconConfig(precision=prec, **kw)
            )
            fn = rec._get_fn("cg", iters)
            y = rec.pack_sino(sino)
            x0 = np.zeros((rec.tomo_pad, 4), np.float32)
            t = timeit(fn, rec._arrays, y, x0, reps=1 if quick else 3)
            if base is None:
                base = t
            emit(
                f"recon_speedup/{level}/{prec}",
                t * 1e6,
                f"speedup={base/t:.2f}x iters={iters}",
            )


if __name__ == "__main__":
    run()
