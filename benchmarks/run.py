"""Benchmark aggregator: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  spmm_fusing     Fig. 9  (a) speedup vs fusing factor, (b) roofline
  recon_speedup   Table III  optimization level x precision
  comm_volumes    Table IV + Fig. 11  per-hierarchy-level volumes
  scaling_*       Fig. 12  strong / weak scaling
  convergence     Fig. 13  residual vs precision (f64 via subprocess)
  stream          Sec. III-E out-of-core: slices/s vs slab size x overlap
  serve           reconstruction-as-a-service: jobs/s, plan-cache hit
                  rate, queue-to-first-slab percentiles

``--quick`` shrinks problem sizes (used by CI).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", default=None,
        help="comma list: "
             "spmm,recon,comms,scaling,convergence,stream,serve",
    )
    ap.add_argument(
        "--trace", action="store_true",
        help="record repro.obs spans in the benches that support it "
             "(spmm, stream); writes TRACE_<suite>.json next to the "
             "BENCH artifacts",
    )
    args = ap.parse_args(argv)

    from . import (
        bench_comms, bench_convergence, bench_recon, bench_scaling,
        bench_serve, bench_spmm, bench_stream, common,
    )

    common.reset()  # fresh BENCH_<suite>.json rows for this invocation

    benches = {
        "spmm": bench_spmm.run,
        "recon": bench_recon.run,
        "comms": bench_comms.run,
        "scaling": bench_scaling.run,
        "convergence": bench_convergence.run,
        "stream": bench_stream.run,
        "serve": bench_serve.run,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if name not in only:
            continue
        try:
            if args.trace and name in ("spmm", "stream"):
                fn(quick=args.quick, trace=True)
            else:
                fn(quick=args.quick)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
