"""Reconstruction-service throughput: plan-cache amortization under a
job mix.

Drives an in-process ``repro.serve.ReconServer`` with a deterministic
six-job traffic mix over two geometries (A cold, B cold, then four
warm re-uses: A A B A) and reports the service-level numbers the
ROADMAP's as-a-service story cares about:

  jobs_per_s            end-to-end service throughput over the mix
                        (machine-normalized by ``tools/bench_check.py``)
  hit_rate              plan-cache hit rate of the mix -- DETERMINISTIC
                        (4 hits / 6 lookups), so it is gated absolutely:
                        any drop means the cache or the fingerprint
                        broke, not a slow runner
  p50/p95_first_slab_s  queue-to-first-slab latency percentiles (the
                        progressive-preview metric; informational)
  warm_speedup          cold vs warm queue-to-first-slab ratio -- the
                        amortization the subsystem exists to buy

Emits ``BENCH_serve.json`` via ``benchmarks.common.emit``; CI's
bench-smoke job runs this with ``--quick`` and gates the guarded fields
against ``benchmarks/baseline/BENCH_serve.json``.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core.geometry import XCTGeometry
from repro.core.partition import PartitionConfig
from repro.core.recon import ReconConfig
from repro.serve import JobSpec, ReconServer

from .common import emit


def _quantile(xs, q: float) -> float:
    return float(np.quantile(np.asarray(xs, np.float64), q))


def run(n: int = 48, iters: int = 6, quick: bool = False):
    if quick:
        n, iters = 32, 4
    y_total = 8 if quick else 16
    y_slab = y_total // 2
    geo_a = XCTGeometry(n=n, n_angles=max(16, n // 2))
    geo_b = XCTGeometry(n=n, n_angles=max(16, n // 2) + 16)
    pcfg = PartitionConfig(
        n_data=1, tile=8, rows_per_block=16, nnz_per_stage=16
    )
    rcfg = ReconConfig(precision="mixed", comm_mode="hier", fuse=2)
    rng = np.random.default_rng(0)

    def spec(geo, tenant):
        sino = rng.standard_normal(
            (geo.n_rays, y_total)
        ).astype(np.float32)
        return JobSpec(
            geo=geo, sino=sino, pcfg=pcfg, rcfg=rcfg, iters=iters,
            tenant=tenant, y_slab=y_slab,
        )

    # A cold, B cold, then warm traffic: 2 misses + 4 hits = 2/3
    mix = [
        spec(geo_a, "t0"), spec(geo_b, "t1"),
        spec(geo_a, "t0"), spec(geo_a, "t2"),
        spec(geo_b, "t1"), spec(geo_a, "t0"),
    ]
    workdir = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        srv = ReconServer(2 * 2**30, workdir=workdir)
        jobs = []
        t0 = time.perf_counter()
        # drain per submit: every job goes through its own cache lookup
        # (a single drain would coalesce same-key jobs into one lookup
        # and make hit_rate depend on arrival timing)
        for s in mix:
            job = srv.submit(s)
            srv.drain()
            jobs.append(job)
        total = time.perf_counter() - t0
        assert all(j.status == "done" for j in jobs), [
            (j.status, j.error) for j in jobs
        ]

        st = srv.stats()
        firsts = [j.telemetry.first_slab_s for j in jobs]
        cold = [j for j in jobs if j.telemetry.plan_cold]
        warm = [j for j in jobs if not j.telemetry.plan_cold]
        cold_first = float(np.mean(
            [j.telemetry.first_slab_s for j in cold]
        ))
        warm_first = float(np.mean(
            [j.telemetry.first_slab_s for j in warm]
        ))
        emit(
            "serve/mix6",
            total / len(jobs) * 1e6,
            f"jobs_per_s={len(jobs) / total:.3f} "
            f"hit_rate={st['hit_rate']:.3f} "
            f"builds={st['builds']} "
            f"p50_first_slab_s={_quantile(firsts, 0.50):.3f} "
            f"p95_first_slab_s={_quantile(firsts, 0.95):.3f} "
            f"n_jobs={len(jobs)} y_slab={y_slab} iters={iters}",
        )
        emit(
            "serve/warm_vs_cold",
            warm_first * 1e6,
            f"cold_first_slab_s={cold_first:.3f} "
            f"warm_first_slab_s={warm_first:.3f} "
            f"warm_speedup={cold_first / max(warm_first, 1e-9):.2f}x",
        )
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
