"""Paper Fig. 12: strong and weak scaling.

Strong: fixed problem, P_d in {1, 2, 4}; weak: n grows with device count
(doubling all measurement dims multiplies work 16x per the paper's
Table I).  On this 1-core container, multi-device wall time measures
*total work + overhead* rather than latency, so the derived column also
reports the analytic per-device work ratio (what a real fleet would see).
Subprocesses are used because the virtual device count must be set before
jax initializes.
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import emit

_SCRIPT = """
import time, numpy as np, jax
from repro.core.geometry import XCTGeometry, build_system_matrix
from repro.core.partition import PartitionConfig, build_plan
from repro.core.recon import ReconConfig, Reconstructor
n, p, iters = {n}, {p}, {iters}
geo = XCTGeometry(n=n, n_angles=n // 2)
a = build_system_matrix(geo)
plan = build_plan(geo, PartitionConfig(n_data=p, tile=4,
                  rows_per_block=16, nnz_per_stage=16), a=a)
mesh = None
if p > 1:
    mesh = jax.make_mesh((1, p), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,)*2)
rec = Reconstructor(plan, mesh=mesh, data_axes=("model",),
    batch_axes=("data",) if p > 1 else (),
    cfg=ReconConfig(precision="mixed", comm_mode="hier", fuse=4))
rng = np.random.default_rng(0)
sino = rng.normal(size=(geo.n_rays, 4)).astype(np.float32)
y = rec.pack_sino(sino); x0 = np.zeros((rec.tomo_pad, 4), np.float32)
fn = rec._get_fn("cg", iters)
jax.block_until_ready(fn(rec._arrays, y, x0))
t0 = time.perf_counter()
jax.block_until_ready(fn(rec._arrays, y, x0))
print("TIME", time.perf_counter() - t0)
"""


def _run_case(n, p, iters=4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={max(p,1)}"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(n=n, p=p, iters=iters)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-800:])
    for line in r.stdout.splitlines():
        if line.startswith("TIME"):
            return float(line.split()[1])
    raise RuntimeError("no TIME in output")


def run(quick: bool = False):
    # strong scaling
    n = 32 if quick else 48
    ps = (1, 2) if quick else (1, 2, 4)
    base = None
    for p in ps:
        t = _run_case(n, p)
        if base is None:
            base = t
        # per-device work ratio from Table I: (MN^2/Pd + MN/sqrt(Pd))
        ideal = (1.0 / p) + 0.1 / np.sqrt(p) if False else 1.0 / p
        emit(
            f"scaling_strong/P={p}", t * 1e6,
            f"eff={(base/t)/p:.2f} ideal_work_frac={ideal:.2f}",
        )
    # weak scaling: n doubles, devices x4 (2D slice work scales n^2*angles)
    cases = [(24, 1), (48, 4)] if not quick else [(16, 1), (32, 4)]
    base = None
    for n_, p_ in cases:
        t = _run_case(n_, p_)
        if base is None:
            base = t
        emit(
            f"scaling_weak/n={n_},P={p_}", t * 1e6,
            f"time_ratio={t/base:.2f} (1.0 = perfect weak scaling "
            f"on a real fleet; 1-core container serializes devices)",
        )


import numpy as np  # noqa: E402

if __name__ == "__main__":
    run()
